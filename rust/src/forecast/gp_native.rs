//! Native-Rust GP regression over history patterns (§3.1.2).
//!
//! Mirrors the L2 JAX model (`python/compile/model.py`) equation-for-
//! equation in f64: same Eq. 5 pattern construction (via
//! `forecast::build_patterns`), same exp/rbf kernels, same jitter, same
//! posterior and log-marginal-likelihood. Cross-validated against the
//! AOT PJRT artifact in `rust/tests/gp_cross_validation.rs`.
//!
//! Used as (a) the fast path for very large simulation sweeps and (b) the
//! reference the PJRT path is checked against. Hyper-parameters follow
//! the paper's evidence maximization: a small lengthscale grid scored by
//! the LML on standardized data.

use super::{build_patterns, naive_forecast, Forecast, Forecaster};
use crate::config::KernelKind;
use crate::util::linalg::{solve_chol, solve_lower, Mat};

/// Jitter matching `model.JITTER` on the python side.
pub const JITTER: f64 = 1e-6;

/// Default evidence-maximization lengthscale grid, in *per-dimension*
/// standardized units (multiplied by sqrt(pattern_dim) at use).
pub const LS_GRID: [f64; 4] = [0.15, 0.3, 0.6, 1.2];

/// Default observation-noise variance (standardized units).
pub const NOISE: f64 = 0.05;

/// GP posterior output for one query.
#[derive(Debug, Clone, Copy)]
pub struct GpPosterior {
    pub mean: f64,
    pub var: f64,
    pub lml: f64,
}

/// Kernel function on flattened pattern rows.
fn kval(kind: KernelKind, a: &[f64], b: &[f64], ls: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    match kind {
        KernelKind::Exp => (-(d2 + 1e-12).sqrt() / ls).exp(),
        KernelKind::Rbf => (-0.5 * d2 / (ls * ls)).exp(),
    }
}

/// Exact GP posterior (mean, var, lml) for flattened inputs:
/// `x_train` is n rows of length p; unit signal variance (standardized y).
pub fn gp_posterior(
    kind: KernelKind,
    x_train: &[f64],
    y_train: &[f64],
    x_query: &[f64],
    p: usize,
    ls: f64,
    noise: f64,
) -> Result<GpPosterior, String> {
    let n = y_train.len();
    assert_eq!(x_train.len(), n * p, "x_train shape");
    assert_eq!(x_query.len(), p, "x_query shape");
    let row = |i: usize| &x_train[i * p..(i + 1) * p];

    let mut kxx = Mat::from_fn(n, n, |i, j| kval(kind, row(i), row(j), ls));
    for i in 0..n {
        kxx[(i, i)] += noise + JITTER;
    }
    let chol = kxx.cholesky().map_err(|e| e.to_string())?;
    let alpha = solve_chol(&chol, y_train);
    let kxq: Vec<f64> = (0..n).map(|i| kval(kind, x_query, row(i), ls)).collect();
    let mean: f64 = kxq.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let v = solve_lower(&chol, &kxq);
    let var = (1.0 - v.iter().map(|x| x * x).sum::<f64>()).max(0.0);
    let mut logdet_half = 0.0;
    for i in 0..n {
        logdet_half += chol[(i, i)].ln();
    }
    let lml = -0.5 * y_train.iter().zip(&alpha).map(|(a, b)| a * b).sum::<f64>()
        - logdet_half
        - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    Ok(GpPosterior { mean, var, lml })
}

/// Native GP forecaster with per-series evidence-maximized lengthscale.
#[derive(Debug, Clone)]
pub struct GpNative {
    pub kernel: KernelKind,
    pub history: usize,
    pub ls_grid: Vec<f64>,
    pub noise: f64,
}

impl GpNative {
    /// Standard configuration (paper: h past observations, exp kernel).
    pub fn new(kernel: KernelKind, history: usize) -> Self {
        GpNative { kernel, history, ls_grid: LS_GRID.to_vec(), noise: NOISE }
    }

    /// Forecast one series: returns the grid-best posterior.
    ///
    /// Grid lengthscales are *per-dimension*: the absolute lengthscale is
    /// `ls * sqrt(p)` so that pattern-space distances (which grow like
    /// sqrt(p) for p-dimensional standardized patterns) stay comparable
    /// across history windows — without this, larger h systematically
    /// underfits.
    pub fn forecast_one(&self, series: &[f64]) -> Forecast {
        if series.len() < 2 {
            return naive_forecast(series);
        }
        let h = self.history;
        let p = h + 1;
        let dim_scale = (p as f64).sqrt();
        let (x, y, q, std) = build_patterns(series, h);
        let mut best: Option<GpPosterior> = None;
        for &ls_rel in &self.ls_grid {
            let ls = ls_rel * dim_scale;
            if let Ok(post) = gp_posterior(self.kernel, &x, &y, &q, p, ls, self.noise) {
                if best.as_ref().map(|b| post.lml > b.lml).unwrap_or(true) {
                    best = Some(post);
                }
            }
        }
        match best {
            Some(post) => Forecast {
                mean: std.inv_mean(post.mean),
                var: std.inv_var(post.var).max(1e-8),
            },
            None => naive_forecast(series),
        }
    }
}

impl Forecaster for GpNative {
    fn name(&self) -> String {
        format!("gp-native-{}-h{}", self.kernel.name(), self.history)
    }

    fn min_history(&self) -> usize {
        // one full window is ideal, but padding handles less; require a
        // quarter window for a meaningful pattern
        (self.history / 2).max(3)
    }

    fn forecast(&mut self, series: &[Vec<f64>]) -> Vec<Forecast> {
        series.iter().map(|s| self.forecast_one(s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn periodic_series(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg::seeded(seed);
        (0..n)
            .map(|i| 0.4 + 0.2 * (i as f64 / 6.0).sin() + 0.01 * rng.normal())
            .collect()
    }

    #[test]
    fn posterior_interpolates_training_point() {
        let h = 5;
        let s = periodic_series(2 * h, 1);
        let (x, y, q0, _) = build_patterns(&s, h);
        let p = h + 1;
        // query at a training row with tiny noise -> mean ~ target
        let row3: Vec<f64> = x[3 * p..4 * p].to_vec();
        let post =
            gp_posterior(KernelKind::Exp, &x, &y, &row3, p, 1.0, 1e-6).unwrap();
        assert!((post.mean - y[3]).abs() < 0.05, "{} vs {}", post.mean, y[3]);
        // and much smaller variance than a far query
        let far = gp_posterior(KernelKind::Exp, &x, &y, &q0, p, 1.0, 1e-6).unwrap();
        assert!(post.var <= far.var + 1e-6);
    }

    #[test]
    fn variance_nonnegative_and_bounded() {
        let h = 8;
        let s = periodic_series(3 * h, 2);
        let (x, y, q, _) = build_patterns(&s, h);
        for kind in [KernelKind::Exp, KernelKind::Rbf] {
            for &ls in &LS_GRID {
                let post = gp_posterior(kind, &x, &y, &q, h + 1, ls, 0.05).unwrap();
                assert!(post.var >= 0.0 && post.var <= 1.0 + 1e-9);
                assert!(post.lml.is_finite());
            }
        }
    }

    #[test]
    fn forecasts_periodic_signal() {
        let gp = GpNative::new(KernelKind::Exp, 10);
        let n = 60;
        let s = periodic_series(n, 3);
        let f = gp.forecast_one(&s[..n - 1]);
        let actual = s[n - 1];
        assert!((f.mean - actual).abs() < 0.1, "pred {} actual {}", f.mean, actual);
        assert!(f.var > 0.0);
    }

    #[test]
    fn sudden_change_inflates_variance() {
        let gp = GpNative::new(KernelKind::Exp, 10);
        let mut smooth = vec![0.4; 30];
        let f_smooth = gp.forecast_one(&smooth);
        // inject an abrupt jump the history has never seen
        for v in smooth.iter_mut().skip(26) {
            *v = 0.9;
        }
        let f_jump = gp.forecast_one(&smooth);
        assert!(
            f_jump.var > f_smooth.var,
            "jump {} vs smooth {}",
            f_jump.var,
            f_smooth.var
        );
    }

    #[test]
    fn evidence_picks_reasonable_lengthscale() {
        // smooth series: RBF with larger ls should win over tiny ls
        let s: Vec<f64> = (0..40).map(|i| 0.5 + 0.1 * (i as f64 / 15.0).sin()).collect();
        let h = 10;
        let (x, y, q, _) = build_patterns(&s, h);
        let lml_small = gp_posterior(KernelKind::Rbf, &x, &y, &q, h + 1, 0.1, 0.05)
            .unwrap()
            .lml;
        let lml_large = gp_posterior(KernelKind::Rbf, &x, &y, &q, h + 1, 2.0, 0.05)
            .unwrap()
            .lml;
        assert!(lml_large > lml_small);
    }

    #[test]
    fn forecaster_trait_batch() {
        let mut gp = GpNative::new(KernelKind::Rbf, 10);
        let out = gp.forecast(&[periodic_series(40, 4), vec![0.3], periodic_series(15, 5)]);
        assert_eq!(out.len(), 3);
        for f in &out {
            assert!(f.mean.is_finite() && f.var >= 0.0);
        }
    }
}
