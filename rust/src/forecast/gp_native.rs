//! Native-Rust GP regression over history patterns (§3.1.2).
//!
//! Mirrors the L2 JAX model (`python/compile/model.py`) equation-for-
//! equation in f64: same Eq. 5 pattern construction (via
//! `forecast::build_patterns`), same exp/rbf kernels, same jitter, same
//! posterior and log-marginal-likelihood. Cross-validated against the
//! AOT PJRT artifact in `rust/tests/gp_cross_validation.rs`.
//!
//! Used as (a) the fast path for very large simulation sweeps and (b) the
//! reference the PJRT path is checked against. Hyper-parameters follow
//! the paper's evidence maximization: a small lengthscale grid scored by
//! the LML on standardized data.
//!
//! # Hot-path architecture
//!
//! The shaping loop forecasts every monitored component each tick, so the
//! per-series cost is engineered around [`GpWorkspace`]:
//!
//! * the pairwise squared-distance Gram matrix is computed **once per
//!   series** and every grid lengthscale's kernel matrix is derived from
//!   it — the distance term is lengthscale-independent, so the O(n²·p)
//!   distance work is paid once instead of once per grid entry;
//! * Cholesky and the triangular solves run **in place** on workspace
//!   buffers (`util::linalg::*_in_place`), so the steady state allocates
//!   nothing;
//! * [`GpNative::forecast_batch`] shards a batch across cores with the
//!   scoped-thread pool (`util::pool`), one workspace per worker, with
//!   results identical for any worker count;
//! * the distance/kern-row/solve inner loops route through the
//!   [`crate::util::simd`] dispatch layer — AVX2+FMA on capable CPUs,
//!   the exact historical scalar sequence otherwise (`ZOE_SIMD=off`).
//!
//! [`gp_posterior`] is the slow-but-obvious reference implementation the
//! workspace path is property-tested against (<= 1e-10; with the scalar
//! SIMD backend the two perform the same float ops in the same order).

use super::{
    build_patterns, build_patterns_into, naive_forecast, Forecast, Forecaster, PatternBufs,
    SeriesRef,
};
use crate::config::KernelKind;
use crate::util::linalg::{
    cholesky_in_place, solve_chol, solve_lower, solve_lower_in_place, solve_lower_t_in_place,
    LinalgError, Mat,
};
use crate::util::pool;
use crate::util::simd;

/// Jitter matching `model.JITTER` on the python side.
pub const JITTER: f64 = 1e-6;

/// Default evidence-maximization lengthscale grid, in *per-dimension*
/// standardized units (multiplied by sqrt(pattern_dim) at use).
pub const LS_GRID: [f64; 4] = [0.15, 0.3, 0.6, 1.2];

/// Default observation-noise variance (standardized units).
pub const NOISE: f64 = 0.05;

/// Below this many series per worker, extra threads cost more than they
/// save (thread spawn is tens of µs; one series is ~10 µs of GP math).
const MIN_SERIES_PER_WORKER: usize = 16;

/// GP posterior output for one query.
#[derive(Debug, Clone, Copy)]
pub struct GpPosterior {
    pub mean: f64,
    pub var: f64,
    pub lml: f64,
}

/// Squared euclidean distance between two flattened pattern rows
/// (vectorized through the SIMD dispatch layer).
#[inline]
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    simd::sum_sq_diff(a, b)
}

/// Kernel value from a precomputed squared distance. Shared with the
/// sliding-window engine (`gp_incremental`), which derives its distances
/// from raw-window sums instead of standardized pattern rows.
#[inline]
pub(crate) fn kern(kind: KernelKind, d2: f64, ls: f64) -> f64 {
    match kind {
        KernelKind::Exp => (-(d2 + 1e-12).sqrt() / ls).exp(),
        KernelKind::Rbf => (-0.5 * d2 / (ls * ls)).exp(),
    }
}

/// Kernel function on flattened pattern rows.
fn kval(kind: KernelKind, a: &[f64], b: &[f64], ls: f64) -> f64 {
    kern(kind, sqdist(a, b), ls)
}

/// Apply the kernel over a row of precomputed squared distances:
/// `out[j] = kern(kind, d2[j], ls)`, vectorized where the SIMD layer is
/// active. Bit-identical to calling [`kern`] per element (the vector
/// path keeps `exp` scalar per lane — see `util::simd`). Shared with
/// `gp_incremental`'s factor assembly.
#[inline]
pub(crate) fn kern_row(kind: KernelKind, d2: &[f64], ls: f64, out: &mut [f64]) {
    match kind {
        KernelKind::Exp => simd::kern_exp_row(d2, ls, out),
        KernelKind::Rbf => simd::kern_rbf_row(d2, ls, out),
    }
}

/// Exact GP posterior (mean, var, lml) for flattened inputs:
/// `x_train` is n rows of length p; unit signal variance (standardized y).
///
/// This is the reference implementation: one fresh kernel matrix and
/// factorization per call. The hot path ([`GpWorkspace`]) reuses the
/// distance Gram and scratch buffers across the lengthscale grid and is
/// property-tested to agree with this function to <= 1e-10.
pub fn gp_posterior(
    kind: KernelKind,
    x_train: &[f64],
    y_train: &[f64],
    x_query: &[f64],
    p: usize,
    ls: f64,
    noise: f64,
) -> Result<GpPosterior, String> {
    let n = y_train.len();
    assert_eq!(x_train.len(), n * p, "x_train shape");
    assert_eq!(x_query.len(), p, "x_query shape");
    let row = |i: usize| &x_train[i * p..(i + 1) * p];

    let mut kxx = Mat::from_fn(n, n, |i, j| kval(kind, row(i), row(j), ls));
    for i in 0..n {
        kxx[(i, i)] += noise + JITTER;
    }
    let chol = kxx.cholesky().map_err(|e| e.to_string())?;
    let alpha = solve_chol(&chol, y_train);
    let kxq: Vec<f64> = (0..n).map(|i| kval(kind, x_query, row(i), ls)).collect();
    let mean: f64 = kxq.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let v = solve_lower(&chol, &kxq);
    let var = (1.0 - v.iter().map(|x| x * x).sum::<f64>()).max(0.0);
    let mut logdet_half = 0.0;
    for i in 0..n {
        logdet_half += chol[(i, i)].ln();
    }
    let lml = -0.5 * y_train.iter().zip(&alpha).map(|(a, b)| a * b).sum::<f64>()
        - logdet_half
        - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    Ok(GpPosterior { mean, var, lml })
}

/// Reusable per-series scratch for the GP hot path.
///
/// `load` builds the Eq. 5 patterns and the pairwise squared-distance
/// Gram matrix once; `posterior` then evaluates any number of
/// lengthscales against that shared state, factoring and solving in
/// place. After the first series of a given window size, no call here
/// touches the allocator.
#[derive(Debug, Clone, Default)]
pub struct GpWorkspace {
    /// Pattern buffers (x: n*p, y: n, q: p), standardized units.
    pat: PatternBufs,
    /// n*n pairwise squared distances between training rows.
    d2: Vec<f64>,
    /// n squared distances query -> training row.
    d2q: Vec<f64>,
    /// n x n kernel matrix, factored in place per lengthscale.
    kxx: Mat,
    /// Query-to-train kernel vector.
    kxq: Vec<f64>,
    /// K⁻¹ y solve buffer.
    alpha: Vec<f64>,
    /// L⁻¹ k* solve buffer (predictive variance).
    v: Vec<f64>,
    /// Training-row count of the loaded series (0 = nothing loaded).
    n: usize,
}

impl GpWorkspace {
    /// Empty workspace; buffers grow on first use and are reused after.
    pub fn new() -> Self {
        GpWorkspace::default()
    }

    /// Load a series: build patterns for history `h` and compute the
    /// lengthscale-independent squared-distance Gram (training pairs and
    /// query-to-training). Returns the window standardizer.
    pub fn load(&mut self, series: &[f64], h: usize) -> super::Standardizer {
        let std = build_patterns_into(series, h, &mut self.pat);
        let p = h + 1;
        let n = self.pat.y.len();
        self.n = n;
        // lower triangle only (incl. diagonal): `posterior` reads
        // d2[i*n + j] exclusively for j <= i
        self.d2.clear();
        self.d2.resize(n * n, 0.0);
        for i in 0..n {
            let row_i = &self.pat.x[i * p..(i + 1) * p];
            for j in 0..=i {
                self.d2[i * n + j] = sqdist(row_i, &self.pat.x[j * p..(j + 1) * p]);
            }
        }
        self.d2q.clear();
        for i in 0..n {
            self.d2q.push(sqdist(&self.pat.q, &self.pat.x[i * p..(i + 1) * p]));
        }
        std
    }

    /// Posterior at one absolute lengthscale for the loaded series,
    /// deriving the kernel matrix from the shared distance Gram and
    /// solving entirely in workspace buffers.
    pub fn posterior(
        &mut self,
        kind: KernelKind,
        ls: f64,
        noise: f64,
    ) -> Result<GpPosterior, LinalgError> {
        let GpWorkspace { pat, d2, d2q, kxx, kxq, alpha, v, n } = self;
        let n = *n;
        assert!(n > 0, "posterior before load");
        // only the lower triangle is materialized: the in-place Cholesky
        // and both triangular solves never read above the diagonal
        kxx.reset(n, n);
        for i in 0..n {
            let row = kxx.row_mut(i);
            kern_row(kind, &d2[i * n..i * n + i + 1], ls, &mut row[..=i]);
            row[i] += noise + JITTER;
        }
        cholesky_in_place(kxx)?;
        alpha.clear();
        alpha.extend_from_slice(&pat.y);
        solve_lower_in_place(kxx, alpha);
        solve_lower_t_in_place(kxx, alpha);
        kxq.clear();
        kxq.resize(n, 0.0);
        kern_row(kind, d2q, ls, kxq);
        let mean: f64 = simd::dot(kxq, alpha);
        v.clear();
        v.extend_from_slice(kxq);
        solve_lower_in_place(kxx, v);
        let var = (1.0 - simd::sum_sq(v)).max(0.0);
        let mut logdet_half = 0.0;
        for i in 0..n {
            logdet_half += kxx[(i, i)].ln();
        }
        let lml = -0.5 * simd::dot(&pat.y, alpha)
            - logdet_half
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
        Ok(GpPosterior { mean, var, lml })
    }
}

/// Native GP forecaster with per-series evidence-maximized lengthscale.
#[derive(Debug, Clone)]
pub struct GpNative {
    pub kernel: KernelKind,
    pub history: usize,
    pub ls_grid: Vec<f64>,
    pub noise: f64,
    /// Worker-thread cap for `forecast_batch`: 0 = auto (available
    /// parallelism / `ZOE_WORKERS`); the effective count is additionally
    /// clamped so each worker gets a worthwhile shard.
    pub workers: usize,
}

impl GpNative {
    /// Standard configuration (paper: h past observations, exp kernel).
    pub fn new(kernel: KernelKind, history: usize) -> Self {
        GpNative {
            kernel,
            history,
            ls_grid: LS_GRID.to_vec(),
            noise: NOISE,
            workers: 0,
        }
    }

    /// Set the worker-thread cap (0 = auto). Results are identical for
    /// any setting; only throughput changes.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Worker count actually used for a batch of `batch` series.
    fn effective_workers(&self, batch: usize) -> usize {
        let cap = if self.workers == 0 { pool::num_workers() } else { self.workers };
        cap.min(batch / MIN_SERIES_PER_WORKER).max(1)
    }

    /// Forecast one series into caller-provided workspace scratch:
    /// returns the grid-best posterior. This is the hot path.
    ///
    /// Grid lengthscales are *per-dimension*: the absolute lengthscale is
    /// `ls * sqrt(p)` so that pattern-space distances (which grow like
    /// sqrt(p) for p-dimensional standardized patterns) stay comparable
    /// across history windows — without this, larger h systematically
    /// underfits.
    ///
    /// Grid entries whose Cholesky fails are skipped individually; when
    /// any fail, one warning is logged for the series (not one per
    /// entry, not silence) so sweeps can see ill-conditioned windows.
    pub fn forecast_one_with(&self, ws: &mut GpWorkspace, series: &[f64]) -> Forecast {
        if series.len() < 2 {
            return naive_forecast(series);
        }
        let dim_scale = ((self.history + 1) as f64).sqrt();
        let std = ws.load(series, self.history);
        let mut best: Option<GpPosterior> = None;
        let mut failed = 0usize;
        let mut last_err: Option<LinalgError> = None;
        for &ls_rel in &self.ls_grid {
            let ls = ls_rel * dim_scale;
            match ws.posterior(self.kernel, ls, self.noise) {
                Ok(post) => {
                    if best.as_ref().map(|b| post.lml > b.lml).unwrap_or(true) {
                        best = Some(post);
                    }
                }
                Err(e) => {
                    failed += 1;
                    last_err = Some(e);
                }
            }
        }
        if failed > 0 {
            crate::warn_log!(
                "gp: {}/{} grid lengthscales failed Cholesky on a {}-point series ({}); {}",
                failed,
                self.ls_grid.len(),
                series.len(),
                last_err.expect("failed > 0"),
                if failed == self.ls_grid.len() {
                    "falling back to the naive forecast"
                } else {
                    "using the surviving grid entries"
                }
            );
        }
        match best {
            Some(post) => Forecast {
                mean: std.inv_mean(post.mean),
                var: std.inv_var(post.var).max(1e-8),
            },
            None => naive_forecast(series),
        }
    }

    /// Forecast one series with a throwaway workspace. Prefer
    /// [`GpNative::forecast_batch`] (or hold a [`GpWorkspace`] and call
    /// `forecast_one_with`) on hot paths.
    pub fn forecast_one(&self, series: &[f64]) -> Forecast {
        self.forecast_one_with(&mut GpWorkspace::new(), series)
    }

    /// Reference forecast: the pre-workspace implementation, one fresh
    /// `gp_posterior` per grid entry. Kept as the correctness oracle and
    /// the old-vs-new baseline in `benches/hotpaths.rs`; not used on any
    /// hot path.
    pub fn forecast_one_reference(&self, series: &[f64]) -> Forecast {
        if series.len() < 2 {
            return naive_forecast(series);
        }
        let h = self.history;
        let p = h + 1;
        let dim_scale = (p as f64).sqrt();
        let (x, y, q, std) = build_patterns(series, h);
        let mut best: Option<GpPosterior> = None;
        for &ls_rel in &self.ls_grid {
            let ls = ls_rel * dim_scale;
            if let Ok(post) = gp_posterior(self.kernel, &x, &y, &q, p, ls, self.noise) {
                if best.as_ref().map(|b| post.lml > b.lml).unwrap_or(true) {
                    best = Some(post);
                }
            }
        }
        match best {
            Some(post) => Forecast {
                mean: std.inv_mean(post.mean),
                var: std.inv_var(post.var).max(1e-8),
            },
            None => naive_forecast(series),
        }
    }

    /// Forecast a batch of borrowed views, sharded across worker threads
    /// (one workspace per worker). Output order matches input order and
    /// every value is identical regardless of the worker count.
    pub fn forecast_batch(&self, series: &[SeriesRef<'_>]) -> Vec<Forecast> {
        let workers = self.effective_workers(series.len());
        pool::shard_map(series, workers, GpWorkspace::new, |ws, _i, s| {
            self.forecast_one_with(ws, s.data)
        })
    }
}

impl Forecaster for GpNative {
    fn name(&self) -> String {
        format!("gp-native-{}-h{}", self.kernel.name(), self.history)
    }

    fn min_history(&self) -> usize {
        // one full window is ideal, but padding handles less; require a
        // quarter window for a meaningful pattern
        (self.history / 2).max(3)
    }

    fn forecast(&mut self, series: &[SeriesRef<'_>]) -> Vec<Forecast> {
        self.forecast_batch(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn periodic_series(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg::seeded(seed);
        (0..n)
            .map(|i| 0.4 + 0.2 * (i as f64 / 6.0).sin() + 0.01 * rng.normal())
            .collect()
    }

    #[test]
    fn posterior_interpolates_training_point() {
        let h = 5;
        let s = periodic_series(2 * h, 1);
        let (x, y, q0, _) = build_patterns(&s, h);
        let p = h + 1;
        // query at a training row with tiny noise -> mean ~ target
        let row3: Vec<f64> = x[3 * p..4 * p].to_vec();
        let post =
            gp_posterior(KernelKind::Exp, &x, &y, &row3, p, 1.0, 1e-6).unwrap();
        assert!((post.mean - y[3]).abs() < 0.05, "{} vs {}", post.mean, y[3]);
        // and much smaller variance than a far query
        let far = gp_posterior(KernelKind::Exp, &x, &y, &q0, p, 1.0, 1e-6).unwrap();
        assert!(post.var <= far.var + 1e-6);
    }

    #[test]
    fn variance_nonnegative_and_bounded() {
        let h = 8;
        let s = periodic_series(3 * h, 2);
        let (x, y, q, _) = build_patterns(&s, h);
        for kind in [KernelKind::Exp, KernelKind::Rbf] {
            for &ls in &LS_GRID {
                let post = gp_posterior(kind, &x, &y, &q, h + 1, ls, 0.05).unwrap();
                assert!(post.var >= 0.0 && post.var <= 1.0 + 1e-9);
                assert!(post.lml.is_finite());
            }
        }
    }

    #[test]
    fn workspace_posterior_matches_reference() {
        let h = 8;
        let s = periodic_series(3 * h, 12);
        let (x, y, q, _) = build_patterns(&s, h);
        let p = h + 1;
        let mut ws = GpWorkspace::new();
        for kind in [KernelKind::Exp, KernelKind::Rbf] {
            ws.load(&s, h);
            for &ls in &LS_GRID {
                let a = ws.posterior(kind, ls, 0.05).unwrap();
                let b = gp_posterior(kind, &x, &y, &q, p, ls, 0.05).unwrap();
                assert!((a.mean - b.mean).abs() <= 1e-10, "{kind:?} ls={ls}");
                assert!((a.var - b.var).abs() <= 1e-10, "{kind:?} ls={ls}");
                assert!((a.lml - b.lml).abs() <= 1e-10, "{kind:?} ls={ls}");
            }
        }
    }

    #[test]
    fn forecasts_periodic_signal() {
        let gp = GpNative::new(KernelKind::Exp, 10);
        let n = 60;
        let s = periodic_series(n, 3);
        let f = gp.forecast_one(&s[..n - 1]);
        let actual = s[n - 1];
        assert!((f.mean - actual).abs() < 0.1, "pred {} actual {}", f.mean, actual);
        assert!(f.var > 0.0);
    }

    #[test]
    fn sudden_change_inflates_variance() {
        let gp = GpNative::new(KernelKind::Exp, 10);
        let mut smooth = vec![0.4; 30];
        let f_smooth = gp.forecast_one(&smooth);
        // inject an abrupt jump the history has never seen
        for v in smooth.iter_mut().skip(26) {
            *v = 0.9;
        }
        let f_jump = gp.forecast_one(&smooth);
        assert!(
            f_jump.var > f_smooth.var,
            "jump {} vs smooth {}",
            f_jump.var,
            f_smooth.var
        );
    }

    #[test]
    fn evidence_picks_reasonable_lengthscale() {
        // smooth series: RBF with larger ls should win over tiny ls
        let s: Vec<f64> = (0..40).map(|i| 0.5 + 0.1 * (i as f64 / 15.0).sin()).collect();
        let h = 10;
        let (x, y, q, _) = build_patterns(&s, h);
        let lml_small = gp_posterior(KernelKind::Rbf, &x, &y, &q, h + 1, 0.1, 0.05)
            .unwrap()
            .lml;
        let lml_large = gp_posterior(KernelKind::Rbf, &x, &y, &q, h + 1, 2.0, 0.05)
            .unwrap()
            .lml;
        assert!(lml_large > lml_small);
    }

    #[test]
    fn forecaster_trait_batch() {
        let mut gp = GpNative::new(KernelKind::Rbf, 10);
        let batch = [periodic_series(40, 4), vec![0.3], periodic_series(15, 5)];
        let out = gp.forecast(&crate::forecast::anon_refs(&batch));
        assert_eq!(out.len(), 3);
        for f in &out {
            assert!(f.mean.is_finite() && f.var >= 0.0);
        }
    }

    #[test]
    fn workspace_reuse_across_series_is_clean() {
        // leftover state from a longer series must not leak into the next
        let gp = GpNative::new(KernelKind::Exp, 10);
        let long = periodic_series(64, 6);
        let short = periodic_series(18, 7);
        let mut ws = GpWorkspace::new();
        let _ = gp.forecast_one_with(&mut ws, &long);
        let reused = gp.forecast_one_with(&mut ws, &short);
        let fresh = gp.forecast_one(&short);
        assert_eq!(reused.mean, fresh.mean);
        assert_eq!(reused.var, fresh.var);
    }

    #[test]
    fn batch_matches_forecast_one() {
        let gp = GpNative::new(KernelKind::Exp, 10);
        let batch: Vec<Vec<f64>> = (0..20).map(|i| periodic_series(40, 100 + i)).collect();
        let out = gp.forecast_batch(&crate::forecast::anon_refs(&batch));
        for (i, s) in batch.iter().enumerate() {
            let one = gp.forecast_one(s);
            assert_eq!(out[i].mean, one.mean, "series {i}");
            assert_eq!(out[i].var, one.var, "series {i}");
        }
    }
}
