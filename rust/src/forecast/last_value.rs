//! Naive last-value (random-walk) forecaster: the sanity baseline every
//! time-series comparison needs. Mean = last observation; variance = the
//! empirical variance of one-step changes.

use super::{naive_forecast, Forecast, Forecaster, SeriesRef};

/// Last-value forecaster (stateless).
#[derive(Debug, Default, Clone)]
pub struct LastValue;

impl LastValue {
    /// Construct.
    pub fn new() -> Self {
        LastValue
    }
}

impl Forecaster for LastValue {
    fn name(&self) -> String {
        "last-value".into()
    }

    fn min_history(&self) -> usize {
        1
    }

    fn forecast(&mut self, series: &[SeriesRef<'_>]) -> Vec<Forecast> {
        series.iter().map(|s| naive_forecast(s.data)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::forecast::anon_refs;

    #[test]
    fn predicts_last() {
        let mut lv = LastValue::new();
        let out = lv.forecast(&anon_refs(&[vec![0.1, 0.4, 0.7], vec![0.9]]));
        assert_eq!(out[0].mean, 0.7);
        assert_eq!(out[1].mean, 0.9);
        assert!(out[0].var > 0.0);
    }

    #[test]
    fn variance_tracks_noise() {
        let mut lv = LastValue::new();
        let smooth: Vec<f64> = (0..50).map(|i| 0.5 + 1e-4 * i as f64).collect();
        let noisy: Vec<f64> = (0..50).map(|i| 0.5 + 0.3 * ((i * 7919) % 13) as f64 / 13.0).collect();
        let out = lv.forecast(&anon_refs(&[smooth, noisy]));
        assert!(out[1].var > out[0].var * 10.0);
    }
}
