//! Sliding-window incremental GP forecasting: per-(component, resource)
//! cached Cholesky factors updated by rank-1 operations (§3.1.2 made
//! cheap enough for continuous control — the axis ADARES and Flex argue
//! bounds control-loop frequency).
//!
//! # Why a slide is possible at all
//!
//! The Eq. 5 pattern kernel depends on two ingredients per training-row
//! pair: the *time-coordinate* difference `((i − j)/t)²` — invariant
//! under a window shift, because every row's coordinate shifts by the
//! same `1/t` — and the squared distance between the rows' *history
//! values*. With the standardizer frozen, the value distance of retained
//! row pairs is exactly the distance of the same raw samples one slot
//! earlier. So when the monitor appends one sample, the kernel matrix
//! changes **only** by dropping training row 0 and appending a new last
//! row: `util::linalg::chol_delete_first` (a rank-1 *update* of the
//! shifted factor — see its docs; downdates would arise only when
//! removing the newest row, which a sliding window never does) plus
//! `chol_append_row`. O(h²) per tick per lengthscale instead of the
//! O(h³) Gram rebuild + refactorization.
//!
//! # The epoch model
//!
//! Per-tick re-standardization would perturb every kernel entry and
//! forbid factor reuse, so this forecaster freezes the standardizer per
//! *epoch*: it is refit — together with a full O(h³) refactorization —
//! when the cached state is created, when the window has slid
//! `refresh_every` times since the last refit (default `2h`: one full
//! window turnover, which also bounds rank-1 rounding drift), when the
//! series resets (monitor epoch change in `SeriesRef::seq`), when the
//! slide gap is too large to be worth replaying, or on any numerical
//! failure. Between refits, **zero full Cholesky refactorizations and
//! zero series copies** happen on the slide path.
//!
//! This is a deliberate, documented model variant: `GpNative` refits the
//! standardizer every call, `GpIncremental` per epoch. The stateless
//! `GpNative` math is untouched and remains the repo's bit-exact oracle;
//! this engine is pinned against *its own* per-tick-refactorize twin
//! ([`SlideMode::Refactorize`] — same epochs, same standardizer, factor
//! rebuilt from scratch every tick) to ≤ 1e-9 in
//! `tests/gp_incremental_prop.rs`, and `benches/engine.rs` reports the
//! warm-tick speedup of slide over refactorize.
//!
//! # Lane-parallel batches
//!
//! The per-key cache is partitioned into `L` lanes by stable `key % L`
//! ([`WorkspaceCache`]): a series' entire slide/refit history lives in
//! exactly one lane, so lanes execute on scoped worker threads
//! (`util::pool::shard_for_each_mut`) with no synchronization — and
//! because each forecast reads and writes only lane-local state under a
//! global batch clock, results are **bit-for-bit identical for any lane
//! or worker count** (pinned in `tests/forecast_lanes_prop.rs`).
//! Eviction is decided on the *global* cache size and applied per lane,
//! keeping the decision lane-count independent while the accounting
//! stays lane-local. Lane count resolution: `ZOE_LANES` env, then the
//! `forecast.lanes` config (0 = auto), then the worker count.

use std::collections::HashMap;

use super::gp_native::{kern, kern_row, GpNative, GpWorkspace, JITTER, LS_GRID, NOISE};
use super::{naive_forecast, Forecast, Forecaster, SeriesRef, Standardizer};
use crate::config::KernelKind;
use crate::util::linalg::{
    chol_append_row, chol_delete_first, cholesky_in_place, solve_lower_in_place,
    solve_lower_t_in_place, Mat,
};
use crate::util::pool;
use crate::util::simd;

/// How the cached factor is maintained when the window slides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlideMode {
    /// Rank-1 delete-first + append-last on the cached factor (O(h²)).
    Incremental,
    /// Rebuild the kernel matrix and refactorize from scratch every tick
    /// (O(h³)) — same epochs and standardizer, so it computes the same
    /// model. The correctness baseline and bench comparator.
    Refactorize,
}

/// Telemetry for tests, benches and capacity planning.
#[derive(Debug, Clone, Copy, Default)]
pub struct IncrStats {
    /// Single-sample window slides performed on the rank-1 path.
    pub slides: u64,
    /// Full refits: standardizer refresh + O(h³) factorization (epoch
    /// starts, resets, large gaps, numerical fallbacks).
    pub refits: u64,
    /// Per-tick full refactorizations (only in [`SlideMode::Refactorize`]).
    pub refactorizations: u64,
    /// Stateless fallbacks (anonymous keys / windows not yet full).
    pub fallbacks: u64,
    /// Cached states dropped by the size-bound eviction.
    pub evictions: u64,
}

/// One grid lengthscale's cached factor.
#[derive(Debug, Clone, Default)]
struct LsFactor {
    /// n×n lower Cholesky factor of the kernel matrix.
    l: Mat,
    /// False when this lengthscale's factorization failed this epoch
    /// (skipped until the next refit, mirroring `GpNative`'s per-entry
    /// grid skips).
    valid: bool,
}

/// Cached per-(component, resource) sliding state.
#[derive(Debug, Clone)]
struct SeriesState {
    /// `SeriesRef::seq` at the last forecast (epoch-tagged).
    seq: u64,
    /// Batch clock at the last use (eviction generation).
    last_used: u64,
    /// Frozen for the epoch.
    std: Standardizer,
    inv_std2: f64,
    /// Raw sample window, length `2h`, oldest first.
    win: Vec<f64>,
    /// Standardized training targets, length `h`.
    y: Vec<f64>,
    grid: Vec<LsFactor>,
    slides_since_refit: u32,
}

/// Reused numeric scratch (allocation-free steady state).
#[derive(Debug, Default)]
struct Scratch {
    /// Combined (time + value) squared-distance Gram, strict lower
    /// triangle (refits only).
    d2: Vec<f64>,
    /// Old first factor column (`chol_delete_first`).
    col: Vec<f64>,
    /// New kernel row (`chol_append_row`).
    row: Vec<f64>,
    /// Combined (time + value) squared-distance row, staged so the kern
    /// application runs vectorized over a contiguous slice — and, being
    /// lengthscale-independent, computed once per row instead of once
    /// per grid entry.
    drow: Vec<f64>,
    alpha: Vec<f64>,
    v: Vec<f64>,
    kxq: Vec<f64>,
}

/// Copy-out of the scalar configuration, so the per-series math can run
/// on split borrows of the cache without re-borrowing `self`.
#[derive(Clone, Copy)]
struct Cfg {
    kernel: KernelKind,
    noise: f64,
    h: usize,
    dim_scale: f64,
    mode: SlideMode,
    refresh_every: u32,
}

/// Sum of squared differences between two h-sample stretches of the raw
/// window: rows `i` and `j` cover `w[i..i+h]` and `w[j..j+h]`.
#[inline]
fn rawd2(w: &[f64], i: usize, j: usize, h: usize) -> f64 {
    simd::sum_sq_diff(&w[i..i + h], &w[j..j + h])
}

/// One lane of the sharded workspace cache: the series states whose keys
/// map to this lane, plus lane-local workspace, scratch and telemetry.
/// A series' entire slide/refit history lives in exactly one lane, so
/// lanes run on separate threads with no synchronization — and the math
/// is identical for any lane or worker count.
#[derive(Debug, Default)]
struct WorkspaceCache {
    states: HashMap<u64, SeriesState>,
    /// Stateless-fallback workspace (anonymous keys, filling windows).
    ws: GpWorkspace,
    scratch: Scratch,
    stats: IncrStats,
    /// Batch scratch: input positions routed to this lane, input order.
    idxs: Vec<usize>,
    /// Batch scratch: forecasts for `idxs`, same order.
    out: Vec<Forecast>,
}

/// Below this many series per worker, lane threads cost more than they
/// save (mirrors `gp_native`'s batch clamp).
const LANE_MIN_SERIES_PER_WORKER: usize = 16;

/// Lane-count resolution for the sharded workspace cache: the
/// `ZOE_LANES` environment variable (if set and >= 1) wins, then an
/// explicit `requested` count (`forecast.lanes` config / `--lanes`),
/// then the worker-count default ([`pool::num_workers`]). Forecasts are
/// identical for every choice; only throughput changes.
pub fn resolve_lanes(requested: usize) -> usize {
    if let Some(n) = crate::util::env::usize_at_least("ZOE_LANES", 1) {
        return n;
    }
    if requested >= 1 {
        requested
    } else {
        pool::num_workers()
    }
}

fn make_lanes(n: usize) -> Vec<WorkspaceCache> {
    (0..n.max(1)).map(|_| WorkspaceCache::default()).collect()
}

/// Incremental GP forecaster. Config fields mirror [`GpNative`].
#[derive(Debug)]
pub struct GpIncremental {
    pub kernel: KernelKind,
    pub history: usize,
    /// Relative grid lengthscales (absolute = `· sqrt(h+1)`, as in
    /// `GpNative`).
    pub ls_grid: Vec<f64>,
    pub noise: f64,
    mode: SlideMode,
    /// Slides between standardizer refreshes / full refactorizations.
    pub refresh_every: u32,
    /// Cache size bound: when the whole cache (all lanes) outgrows this
    /// after a batch, every state not touched by that batch is dropped
    /// (a dropped series simply refits on its next appearance). Bounds
    /// memory on workloads that churn through many components.
    pub max_cached: usize,
    /// Monotone batch counter (eviction generations).
    clock: u64,
    /// Squared time-coordinate distances `((d)/2h)²` for d in `0..=h`.
    tgrid: Vec<f64>,
    /// Lane-sharded workspace caches (`key % lanes.len()`); never empty.
    lanes: Vec<WorkspaceCache>,
    /// Stateless path for anonymous keys and not-yet-full windows —
    /// exactly `GpNative`'s math, so those forecasts are bit-identical
    /// to the batched engine's.
    fallback: GpNative,
}

impl GpIncremental {
    /// Standard configuration; refresh cadence defaults to one full
    /// window turnover (`2h` slides), lane count to [`resolve_lanes`]'s
    /// auto default.
    pub fn new(kernel: KernelKind, history: usize) -> Self {
        let h = history.max(2);
        let t = (2 * h) as f64;
        GpIncremental {
            kernel,
            history: h,
            ls_grid: LS_GRID.to_vec(),
            noise: NOISE,
            mode: SlideMode::Incremental,
            refresh_every: (2 * h) as u32,
            max_cached: 65_536,
            clock: 0,
            tgrid: (0..=h).map(|d| (d as f64 / t) * (d as f64 / t)).collect(),
            lanes: make_lanes(resolve_lanes(0)),
            fallback: GpNative::new(kernel, h),
        }
    }

    /// Select the factor-maintenance mode (tests and benches; production
    /// is [`SlideMode::Incremental`]).
    pub fn with_mode(mut self, mode: SlideMode) -> Self {
        self.mode = mode;
        self
    }

    /// Pin the lane count exactly (benches/tests pin scaling points).
    /// Unlike [`resolve_lanes`] no environment override applies here.
    /// Drops any cached state.
    pub fn with_lanes(mut self, lanes: usize) -> Self {
        self.lanes = make_lanes(lanes);
        self
    }

    /// Lane count in use.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Telemetry counters, aggregated over lanes.
    pub fn stats(&self) -> IncrStats {
        let mut t = IncrStats::default();
        for lane in &self.lanes {
            t.slides += lane.stats.slides;
            t.refits += lane.stats.refits;
            t.refactorizations += lane.stats.refactorizations;
            t.fallbacks += lane.stats.fallbacks;
            t.evictions += lane.stats.evictions;
        }
        t
    }

    /// Per-lane telemetry (eviction accounting stays lane-local).
    pub fn lane_stats(&self) -> Vec<IncrStats> {
        self.lanes.iter().map(|lane| lane.stats).collect()
    }

    /// Cached series count across all lanes (capacity planning; bounded
    /// by live component count × 2 resources).
    pub fn cached_series(&self) -> usize {
        self.lanes.iter().map(|lane| lane.states.len()).sum()
    }

    /// Drop cached state (e.g. between unrelated workloads).
    pub fn clear_cache(&mut self) {
        for lane in &mut self.lanes {
            lane.states.clear();
        }
    }

    /// Scalar configuration copy-out for the lane workers.
    fn cfg(&self) -> Cfg {
        Cfg {
            kernel: self.kernel,
            noise: self.noise,
            h: self.history,
            dim_scale: ((self.history + 1) as f64).sqrt(),
            mode: self.mode,
            refresh_every: self.refresh_every,
        }
    }

    /// Forecast one view through its lane's cache (single-view path for
    /// unit tests; batches go through [`Forecaster::forecast`]).
    #[cfg(test)]
    fn forecast_view(&mut self, r: &SeriesRef<'_>) -> Forecast {
        let cfg = self.cfg();
        let li = (r.key % self.lanes.len() as u64) as usize;
        let GpIncremental { lanes, fallback, tgrid, ls_grid, clock, .. } = self;
        lane_forecast_view(&mut lanes[li], fallback, cfg, ls_grid, tgrid, *clock, r)
    }
}

/// Forecast one view against its lane's cache. Per-series pure: reads
/// and writes only lane-local state (plus the shared immutable config
/// and fallback engine), which is what makes lane execution
/// embarrassingly parallel *and* bit-for-bit independent of the lane
/// and worker counts.
fn lane_forecast_view(
    lane: &mut WorkspaceCache,
    fallback: &GpNative,
    cfg: Cfg,
    ls_grid: &[f64],
    tgrid: &[f64],
    clock: u64,
    r: &SeriesRef<'_>,
) -> Forecast {
    let h = cfg.h;
    let window = 2 * h;
    if r.data.len() < 2 {
        return naive_forecast(r.data);
    }
    if r.key == SeriesRef::ANON || r.data.len() < window {
        // no identity to cache under, or the window is still filling:
        // the stateless workspace path (== GpNative bit for bit)
        lane.stats.fallbacks += 1;
        return fallback.forecast_one_with(&mut lane.ws, r.data);
    }
    let tail = &r.data[r.data.len() - window..];
    // split borrows: the cache, scratch and stats move independently
    let WorkspaceCache { states, stats, scratch, .. } = lane;

    let st = states.entry(r.key).or_insert_with(|| SeriesState {
        seq: u64::MAX, // forces the refit branch below
        last_used: clock,
        std: Standardizer { mean: 0.0, std: 1.0 },
        inv_std2: 1.0,
        win: Vec::with_capacity(window),
        y: Vec::with_capacity(h),
        grid: vec![LsFactor::default(); ls_grid.len()],
        slides_since_refit: 0,
    });
    st.last_used = clock;

    // decide: how many samples did this series advance since we last
    // saw it, and is replaying them cheaper than refitting?
    let same_epoch = (r.seq >> 32) == (st.seq >> 32);
    let delta = r.seq.wrapping_sub(st.seq);
    let slide_ok = st.seq != u64::MAX
        && same_epoch
        && r.seq >= st.seq
        && (delta as usize) < h
        && st.slides_since_refit.saturating_add(delta as u32) <= cfg.refresh_every;

    let mut ok = true;
    if slide_ok {
        let s = delta as usize;
        for &v in &tail[window - s..] {
            slide_window_one(st, v);
            if cfg.mode == SlideMode::Incremental {
                stats.slides += 1;
                if !slide_factors_one(st, cfg, ls_grid, tgrid, scratch) {
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            debug_assert_eq!(st.win.as_slice(), tail, "sliding-window desync");
        }
        if ok && cfg.mode == SlideMode::Refactorize && s > 0 {
            stats.refactorizations += 1;
            build_factors(st, cfg, ls_grid, tgrid, scratch);
        }
        st.slides_since_refit += delta as u32;
    }
    if !slide_ok || !ok {
        if !ok {
            crate::warn_log!(
                "gp-incr: rank-1 slide lost positive definiteness on series {}; refitting",
                r.key
            );
        }
        stats.refits += 1;
        refit_state(st, tail, cfg, ls_grid, tgrid, scratch);
    }
    st.seq = r.seq;

    match posterior_best(st, cfg, ls_grid, tgrid, scratch) {
        Some((mean_z, var_z)) => Forecast {
            mean: st.std.inv_mean(mean_z),
            var: st.std.inv_var(var_z).max(1e-8),
        },
        None => naive_forecast(r.data),
    }
}

/// Advance the raw window and standardized targets by one sample under
/// the frozen standardizer.
fn slide_window_one(st: &mut SeriesState, v: f64) {
    st.win.rotate_left(1);
    *st.win.last_mut().expect("window non-empty") = v;
    st.y.rotate_left(1);
    // new last target: row h-1's target is win[2h-1] = the new sample
    *st.y.last_mut().expect("targets non-empty") = st.std.fwd(v);
}

/// One rank-1 slide of every valid grid factor against the (already
/// advanced) window. Returns false when any append loses positive
/// definiteness — the caller refits everything.
fn slide_factors_one(
    st: &mut SeriesState,
    cfg: Cfg,
    ls_grid: &[f64],
    tgrid: &[f64],
    scratch: &mut Scratch,
) -> bool {
    let n = cfg.h;
    let Scratch { col, row, drow, .. } = scratch;
    // the new last row's squared-distance profile is lengthscale-
    // independent: stage it once, reuse for every grid entry
    drow.clear();
    for j in 0..n - 1 {
        drow.push(tgrid[n - 1 - j] + rawd2(&st.win, j, n - 1, cfg.h) * st.inv_std2);
    }
    for (g, &ls_rel) in ls_grid.iter().enumerate() {
        let lst = &mut st.grid[g];
        if !lst.valid {
            continue;
        }
        let ls = ls_rel * cfg.dim_scale;
        chol_delete_first(&mut lst.l, n, col);
        row.clear();
        row.resize(n - 1, 0.0);
        kern_row(cfg.kernel, drow, ls, row);
        row.push(kern(cfg.kernel, 0.0, ls) + cfg.noise + JITTER);
        if chol_append_row(&mut lst.l, row).is_err() {
            return false;
        }
    }
    true
}

/// Full O(h³) factor build for every grid lengthscale from the current
/// window (shared by refits and the Refactorize baseline).
fn build_factors(
    st: &mut SeriesState,
    cfg: Cfg,
    ls_grid: &[f64],
    tgrid: &[f64],
    scratch: &mut Scratch,
) {
    let n = cfg.h;
    let Scratch { d2, .. } = scratch;
    // combined (time + value) squared-distance Gram once; every
    // lengthscale derives its kernel matrix from it with a vector
    // kern-row pass over the contiguous strict-lower rows
    d2.clear();
    d2.resize(n * n, 0.0);
    for i in 0..n {
        for j in 0..i {
            d2[i * n + j] = tgrid[i - j] + rawd2(&st.win, i, j, cfg.h) * st.inv_std2;
        }
    }
    let mut failed = 0usize;
    for (g, &ls_rel) in ls_grid.iter().enumerate() {
        let ls = ls_rel * cfg.dim_scale;
        let lst = &mut st.grid[g];
        lst.l.reset(n, n);
        for i in 0..n {
            let lrow = lst.l.row_mut(i);
            kern_row(cfg.kernel, &d2[i * n..i * n + i], ls, &mut lrow[..i]);
            lrow[i] = kern(cfg.kernel, 0.0, ls) + cfg.noise + JITTER;
        }
        lst.valid = cholesky_in_place(&mut lst.l).is_ok();
        if !lst.valid {
            failed += 1;
        }
    }
    if failed > 0 {
        crate::warn_log!(
            "gp-incr: {failed}/{} grid lengthscales failed Cholesky at refit",
            ls_grid.len()
        );
    }
}

/// Start a fresh epoch: refit the standardizer over the window, rebuild
/// targets, refactorize every lengthscale.
fn refit_state(
    st: &mut SeriesState,
    tail: &[f64],
    cfg: Cfg,
    ls_grid: &[f64],
    tgrid: &[f64],
    scratch: &mut Scratch,
) {
    st.std = Standardizer::fit(tail);
    st.inv_std2 = 1.0 / (st.std.std * st.std.std);
    st.win.clear();
    st.win.extend_from_slice(tail);
    st.y.clear();
    for i in 0..cfg.h {
        st.y.push(st.std.fwd(st.win[i + cfg.h]));
    }
    st.slides_since_refit = 0;
    build_factors(st, cfg, ls_grid, tgrid, scratch);
}

/// Evidence-maximized posterior over the valid grid entries:
/// standardized (mean, var) of the best-LML lengthscale.
fn posterior_best(
    st: &SeriesState,
    cfg: Cfg,
    ls_grid: &[f64],
    tgrid: &[f64],
    scratch: &mut Scratch,
) -> Option<(f64, f64)> {
    let n = cfg.h;
    let Scratch { drow, alpha, v, kxq, .. } = scratch;
    // query row: time coord (t-h)/t, history win[h..2h] — the distance
    // profile is lengthscale-independent, staged once for the grid
    drow.clear();
    for j in 0..n {
        drow.push(tgrid[n - j] + rawd2(&st.win, j, cfg.h, cfg.h) * st.inv_std2);
    }
    let mut best: Option<(f64, f64, f64)> = None; // (lml, mean, var)
    for (g, &ls_rel) in ls_grid.iter().enumerate() {
        let lst = &st.grid[g];
        if !lst.valid {
            continue;
        }
        let ls = ls_rel * cfg.dim_scale;
        kxq.clear();
        kxq.resize(n, 0.0);
        kern_row(cfg.kernel, drow, ls, kxq);
        alpha.clear();
        alpha.extend_from_slice(&st.y);
        solve_lower_in_place(&lst.l, alpha);
        solve_lower_t_in_place(&lst.l, alpha);
        let mean: f64 = simd::dot(kxq, alpha);
        v.clear();
        v.extend_from_slice(kxq);
        solve_lower_in_place(&lst.l, v);
        let var = (1.0 - simd::sum_sq(v)).max(0.0);
        let mut logdet_half = 0.0;
        for i in 0..n {
            logdet_half += lst.l[(i, i)].ln();
        }
        let lml = -0.5 * simd::dot(&st.y, alpha)
            - logdet_half
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
        if best.map(|(b, _, _)| lml > b).unwrap_or(true) {
            best = Some((lml, mean, var));
        }
    }
    best.map(|(_, m, v)| (m, v))
}

impl Forecaster for GpIncremental {
    fn name(&self) -> String {
        format!("gp-incr-{}-h{}", self.kernel.name(), self.history)
    }

    fn min_history(&self) -> usize {
        (self.history / 2).max(3)
    }

    fn forecast(&mut self, series: &[SeriesRef<'_>]) -> Vec<Forecast> {
        self.clock += 1;
        let cfg = self.cfg();
        let clock = self.clock;
        let nlanes = self.lanes.len() as u64;
        for lane in &mut self.lanes {
            lane.idxs.clear();
            lane.out.clear();
        }
        // stable partition by key: within a lane, views keep input
        // order, so routing is identical for any lane/worker count
        for (i, r) in series.iter().enumerate() {
            self.lanes[(r.key % nlanes) as usize].idxs.push(i);
        }
        let workers = pool::num_workers()
            .min(series.len() / LANE_MIN_SERIES_PER_WORKER)
            .max(1)
            .min(self.lanes.len());
        {
            let GpIncremental { lanes, fallback, tgrid, ls_grid, .. } = &mut *self;
            let fallback: &GpNative = fallback;
            let ls_grid: &[f64] = ls_grid;
            let tgrid: &[f64] = tgrid;
            pool::shard_for_each_mut(lanes, workers, |_li, lane| {
                // detach the routing list so the lane stays mutably
                // borrowable for the per-series math
                let idxs = std::mem::take(&mut lane.idxs);
                for &i in &idxs {
                    let f =
                        lane_forecast_view(lane, fallback, cfg, ls_grid, tgrid, clock, &series[i]);
                    lane.out.push(f);
                }
                lane.idxs = idxs;
            });
        }
        // scatter lane outputs back to input order
        let mut out = vec![Forecast { mean: 0.0, var: 0.0 }; series.len()];
        for lane in &self.lanes {
            for (&i, f) in lane.idxs.iter().zip(&lane.out) {
                out[i] = *f;
            }
        }
        // eviction: decided on the GLOBAL cache size — a per-lane
        // threshold would make the drop set depend on the lane count —
        // then applied and accounted per lane. Keep only the states
        // this batch touched: components that left the shaped set
        // (finished, gave up, long-preempted) stop costing memory; a
        // returner simply refits.
        let total: usize = self.lanes.iter().map(|lane| lane.states.len()).sum();
        if total > self.max_cached {
            for lane in &mut self.lanes {
                let before = lane.states.len();
                lane.states.retain(|_, st| st.last_used == clock);
                lane.stats.evictions += (before - lane.states.len()) as u64;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn periodic(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg::seeded(seed);
        (0..n)
            .map(|i| 0.45 + 0.2 * (i as f64 / 7.0).sin() + 0.01 * rng.normal())
            .collect()
    }

    #[test]
    fn anonymous_views_match_gp_native_exactly() {
        let mut gp = GpIncremental::new(KernelKind::Exp, 10);
        let native = GpNative::new(KernelKind::Exp, 10);
        for len in [5usize, 15, 19, 40] {
            let s = periodic(len, len as u64);
            let inc = gp.forecast_view(&SeriesRef::anon(&s));
            let nat = native.forecast_one(&s);
            assert_eq!(inc.mean, nat.mean, "len={len}");
            assert_eq!(inc.var, nat.var, "len={len}");
        }
        assert_eq!(gp.cached_series(), 0, "anonymous views must not cache");
        assert!(gp.stats().fallbacks > 0);
    }

    #[test]
    fn short_keyed_views_fall_back_until_window_fills() {
        let h = 10;
        let mut gp = GpIncremental::new(KernelKind::Rbf, h);
        let s = periodic(2 * h - 1, 3); // one short of a full window
        let f = gp.forecast_view(&SeriesRef::keyed(0, s.len() as u64, &s));
        assert!(f.mean.is_finite());
        assert_eq!(gp.cached_series(), 0);
        assert_eq!(gp.stats().fallbacks, 1);
    }

    #[test]
    fn keyed_full_window_builds_cache_and_slides() {
        let h = 10;
        let mut gp = GpIncremental::new(KernelKind::Exp, h);
        let s = periodic(60, 9);
        // first sight: refit
        let f0 = gp.forecast_view(&SeriesRef::keyed(1, 2 * h as u64, &s[..2 * h]));
        assert!(f0.mean.is_finite() && f0.var > 0.0);
        assert_eq!(gp.cached_series(), 1);
        assert_eq!(gp.stats().refits, 1);
        assert_eq!(gp.stats().slides, 0);
        // next ticks: pure slides, no refits
        for t in (2 * h + 1)..(2 * h + 8) {
            let f = gp.forecast_view(&SeriesRef::keyed(1, t as u64, &s[..t]));
            assert!(f.mean.is_finite() && f.var > 0.0);
        }
        assert_eq!(gp.stats().refits, 1, "steady state must not refit");
        assert_eq!(gp.stats().slides, 7);
        assert_eq!(gp.stats().refactorizations, 0);
    }

    #[test]
    fn refresh_cadence_bounds_epoch_length() {
        let h = 5;
        let mut gp = GpIncremental::new(KernelKind::Exp, h);
        gp.refresh_every = 4;
        let s = periodic(120, 21);
        for t in (2 * h)..60 {
            gp.forecast_view(&SeriesRef::keyed(2, t as u64, &s[..t]));
        }
        let st = gp.stats();
        // 50 ticks after the first: a refit at least every 5 ticks
        assert!(st.refits >= 10, "refits {} too rare for cadence 4", st.refits);
        assert!(st.slides > 0);
    }

    #[test]
    fn epoch_change_forces_refit_and_matches_fresh_instance() {
        let h = 8;
        let s = periodic(2 * h, 5);
        let mut warm = GpIncremental::new(KernelKind::Exp, h);
        // warm cache under epoch 0
        warm.forecast_view(&SeriesRef::keyed(3, 2 * h as u64, &s));
        // the component restarted: same key, new epoch in the seq tag
        let s2 = periodic(2 * h, 6);
        let seq2 = (1u64 << 32) | (2 * h as u64);
        let warm_f = warm.forecast_view(&SeriesRef::keyed(3, seq2, &s2));
        let mut fresh = GpIncremental::new(KernelKind::Exp, h);
        let fresh_f = fresh.forecast_view(&SeriesRef::keyed(3, seq2, &s2));
        assert_eq!(warm_f.mean, fresh_f.mean, "refit must ignore stale state");
        assert_eq!(warm_f.var, fresh_f.var);
        assert_eq!(warm.stats().refits, 2);
    }

    #[test]
    fn large_gap_refits_instead_of_replaying() {
        let h = 6;
        let mut gp = GpIncremental::new(KernelKind::Exp, h);
        let s = periodic(100, 13);
        gp.forecast_view(&SeriesRef::keyed(4, 2 * h as u64, &s[..2 * h]));
        // jump far ahead: delta >= h → refit, not h slides
        gp.forecast_view(&SeriesRef::keyed(4, 90, &s[..90]));
        assert_eq!(gp.stats().refits, 2);
        assert_eq!(gp.stats().slides, 0);
    }

    #[test]
    fn cache_eviction_bounds_memory_across_batches() {
        let h = 5;
        let window = 2 * h;
        let mut gp = GpIncremental::new(KernelKind::Exp, h);
        gp.max_cached = 8;
        let corpus: Vec<Vec<f64>> = (0..12).map(|i| periodic(window, 100 + i as u64)).collect();
        // batch A: keys 0..6 — under the bound, nothing evicted
        let views_a: Vec<SeriesRef<'_>> = corpus[..6]
            .iter()
            .enumerate()
            .map(|(i, s)| SeriesRef::keyed(i as u64, window as u64, s))
            .collect();
        gp.forecast(&views_a);
        assert_eq!(gp.cached_series(), 6);
        assert_eq!(gp.stats().evictions, 0);
        // batch B: keys 6..12 — cache would hold 12 > 8, so batch A's
        // untouched states are dropped
        let views_b: Vec<SeriesRef<'_>> = corpus[6..]
            .iter()
            .enumerate()
            .map(|(i, s)| SeriesRef::keyed((6 + i) as u64, window as u64, s))
            .collect();
        gp.forecast(&views_b);
        assert_eq!(gp.cached_series(), 6, "only batch B survives");
        assert_eq!(gp.stats().evictions, 6);
    }

    #[test]
    fn lane_count_does_not_change_forecasts() {
        let h = 6;
        let window = 2 * h;
        let ticks = 30usize;
        let corpus: Vec<Vec<f64>> =
            (0..10).map(|i| periodic(window + ticks, 40 + i as u64)).collect();
        let run = |lanes: usize| {
            let mut gp = GpIncremental::new(KernelKind::Exp, h).with_lanes(lanes);
            assert_eq!(gp.lane_count(), lanes);
            let mut all = Vec::new();
            let mut t = window;
            while t <= window + ticks {
                let views: Vec<SeriesRef<'_>> = corpus
                    .iter()
                    .enumerate()
                    .map(|(i, s)| SeriesRef::keyed(i as u64, t as u64, &s[..t]))
                    .collect();
                all.extend(gp.forecast(&views));
                t += 1 + (t % 2);
            }
            (all, gp.stats())
        };
        let (base, base_stats) = run(1);
        assert!(base_stats.slides > 0);
        for lanes in [2, 3, 8, 16] {
            let (out, stats) = run(lanes);
            assert_eq!(out.len(), base.len());
            for (i, (a, b)) in out.iter().zip(&base).enumerate() {
                assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "lanes={lanes} view {i}");
                assert_eq!(a.var.to_bits(), b.var.to_bits(), "lanes={lanes} view {i}");
            }
            assert_eq!(stats.slides, base_stats.slides, "lanes={lanes}");
            assert_eq!(stats.refits, base_stats.refits, "lanes={lanes}");
        }
    }

    #[test]
    fn same_seq_reuses_factors_verbatim() {
        let h = 8;
        let mut gp = GpIncremental::new(KernelKind::Rbf, h);
        let s = periodic(3 * h, 17);
        let r = SeriesRef::keyed(5, s.len() as u64, &s);
        let a = gp.forecast_view(&r);
        let b = gp.forecast_view(&r);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.var, b.var);
        assert_eq!(gp.stats().refits, 1);
        assert_eq!(gp.stats().slides, 0);
    }
}
