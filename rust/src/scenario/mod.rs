//! Declarative timed scenarios (PR 9): a versioned JSON format —
//! `{id, name, description, steps: [{at, action}]}` — describing *when*
//! the demand model, the cluster shape or the fault schedule changes
//! mid-run, compiled into ordinary discrete-event-queue events the same
//! way `faults::FaultPlan` is (PR 8): everything is a pure function of
//! `(spec, config, seed, horizon)`, so a scenario replays bit-for-bit
//! across repeats, engine modes and `ZOE_WORKERS` sweeps, and an absent
//! or empty scenario leaves the engine bit-for-bit identical to a build
//! without this module (tests/scenario_prop.rs).
//!
//! ## Actions
//!
//! * `set-family` — switch the synthetic workload family
//!   ([`crate::trace::families::FamilyKind`]) from this step on.
//! * `set-arrivals` / `ramp-arrivals` — step or linearly ramp the
//!   arrival-rate factor.
//! * `add-hosts` / `remove-hosts` / `restore-hosts` / `resize-hosts` —
//!   reshape the cluster: add a batch of new machines, drain the
//!   highest-id live machines, bring drained machines back, or replace
//!   machines with a differently-shaped batch in one step.
//! * `fault-window` — inject one explicitly-timed fault window
//!   (telemetry `dropout`/`corruption`, `forecast` faults, or a host
//!   `crash`) on top of whatever `FaultConfig` schedules.
//!
//! ## End semantics
//!
//! An optional top-level `end_s` compiles a final cleanup step: drained
//! base hosts come back, scenario-added hosts drain, and the demand
//! model returns to the baseline family at factor 1.0. Fault windows are
//! clamped to `end_s`. Without `end_s`, step effects persist to the end
//! of the run.
//!
//! Loader errors name the offending step (`step 3 ("surge"): ...`) so a
//! broken library file is diagnosable from the message alone.

use crate::cluster::Cluster;
use crate::config::{ClusterConfig, HostClass};
use crate::faults::{
    self, CrashWindow, FaultPlan, ForecastFaultWindow, TelemetryFault, TelemetryWindow,
};
use crate::trace::families::{FamilyKind, GenTimeline};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::workload::HostId;

/// The scenario file format version this build understands.
pub const SCENARIO_FORMAT_VERSION: u64 = 1;

/// Stream id separating scenario-compile draws (crash host picks,
/// telemetry salts) from the fault plan's `FAULT_STREAM` and the
/// workload generator's direct use of the seed.
const SCENARIO_STREAM: u64 = 0x5CE_A410;

/// Ids of the in-tree scenario library (`scenarios/*.json`), in display
/// order. `sched-sweep --scenario <id>` and `scenarios --run <id>`
/// resolve against this list.
pub const LIBRARY_IDS: [&str; 5] = [
    "diurnal",
    "bursty-onoff",
    "heavy-tail",
    "anti-forecast",
    "mixed-stress",
];

const LIBRARY_SOURCES: [&str; 5] = [
    include_str!("../../../scenarios/diurnal.json"),
    include_str!("../../../scenarios/bursty_onoff.json"),
    include_str!("../../../scenarios/heavy_tail.json"),
    include_str!("../../../scenarios/anti_forecast.json"),
    include_str!("../../../scenarios/mixed_stress.json"),
];

/// What a scenario `fault-window` step injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultWindowKind {
    /// Telemetry dropout: covered components record no samples.
    Dropout,
    /// Telemetry corruption: covered components deliver NaN samples.
    Corruption,
    /// Forecaster fault: model outputs come back non-finite.
    Forecast,
    /// Host crash + recovery at window end.
    Crash,
}

impl FaultWindowKind {
    /// Parse from scenario-file text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dropout" => Some(Self::Dropout),
            "corruption" => Some(Self::Corruption),
            "forecast" => Some(Self::Forecast),
            "crash" => Some(Self::Crash),
            _ => None,
        }
    }

    /// Stable display name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Dropout => "dropout",
            Self::Corruption => "corruption",
            Self::Forecast => "forecast",
            Self::Crash => "crash",
        }
    }
}

/// One scenario action (see the module doc for the JSON encoding).
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioAction {
    /// Switch the synthetic workload family from this step on.
    SetFamily { family: FamilyKind },
    /// Set the arrival-rate factor (multiplier on the base rate).
    SetArrivals { factor: f64 },
    /// Linearly ramp the arrival-rate factor to `to_factor` over
    /// `over_s` seconds.
    RampArrivals { to_factor: f64, over_s: f64 },
    /// Bring `count` new hosts of the given shape online.
    AddHosts { count: usize, cores: f64, mem_gb: f64 },
    /// Drain the `count` highest-id live hosts (components on them are
    /// displaced and re-queued).
    RemoveHosts { count: usize },
    /// Bring back up to `count` previously drained hosts (most recently
    /// drained first).
    RestoreHosts { count: usize },
    /// Replace the `count` highest-id live hosts with `count` new hosts
    /// of a different shape, in one step.
    ResizeHosts { count: usize, cores: f64, mem_gb: f64 },
    /// Inject one explicitly-timed fault window starting at the step.
    FaultWindow {
        kind: FaultWindowKind,
        duration_s: f64,
        /// Component coverage for telemetry kinds, in [0,1] (ignored for
        /// `forecast` and `crash`).
        coverage: f64,
        /// Crash target host (base-cluster id); seeded pick when absent.
        host: Option<HostId>,
    },
}

/// One timed step: `action` takes effect at simulated time `at`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioStep {
    pub at: f64,
    /// Optional human label, used in validation errors.
    pub name: Option<String>,
    pub action: ScenarioAction,
}

/// A parsed, validated scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Stable machine id (library lookup key).
    pub id: String,
    /// Human-readable title.
    pub name: String,
    /// What the scenario exercises.
    pub description: String,
    /// Optional cleanup time: at `end_s` the cluster returns to its
    /// configured shape and the demand model to the baseline.
    pub end_s: Option<f64>,
    /// Timed steps, ascending by `at`.
    pub steps: Vec<ScenarioStep>,
}

/// `"step 3"` or `"step 3 (\"surge\")"` — every loader error leads with
/// this so the offending step is nameable from the message alone.
fn step_label(idx: usize, name: Option<&str>) -> String {
    match name {
        Some(n) => format!("step {idx} (\"{n}\")"),
        None => format!("step {idx}"),
    }
}

impl ScenarioSpec {
    /// Parse and validate a scenario document.
    pub fn from_json(src: &str) -> Result<ScenarioSpec, String> {
        let doc = Json::parse(src).map_err(|e| format!("scenario: {e}"))?;
        let version = doc
            .get("version")
            .and_then(Json::as_f64)
            .ok_or_else(|| "scenario: missing numeric \"version\"".to_string())?;
        if version != SCENARIO_FORMAT_VERSION as f64 {
            return Err(format!(
                "scenario: unsupported scenario version {version} (supported: {SCENARIO_FORMAT_VERSION})"
            ));
        }
        let id = doc
            .get("id")
            .and_then(Json::as_str)
            .ok_or_else(|| "scenario: missing string \"id\"".to_string())?
            .to_string();
        let name = doc.get("name").and_then(Json::as_str).unwrap_or(&id).to_string();
        let description = doc
            .get("description")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let end_s = doc.get("end_s").and_then(Json::as_f64);
        let raw_steps = doc
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| "scenario: missing array \"steps\"".to_string())?;
        let mut steps = Vec::with_capacity(raw_steps.len());
        for (idx, raw) in raw_steps.iter().enumerate() {
            steps.push(parse_step(idx, raw)?);
        }
        let spec = ScenarioSpec { id, name, description, end_s, steps };
        spec.validate()?;
        Ok(spec)
    }

    /// Load and validate a scenario file.
    pub fn load(path: &str) -> Result<ScenarioSpec, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("scenario: cannot read {path}: {e}"))?;
        Self::from_json(&src).map_err(|e| format!("{path}: {e}"))
    }

    /// Semantic validation (also run by [`ScenarioSpec::from_json`] and
    /// delegated to from `SimConfig::validate`). Every error names the
    /// offending step.
    pub fn validate(&self) -> Result<(), String> {
        if self.id.is_empty() {
            return Err("scenario: \"id\" must be non-empty".into());
        }
        if let Some(end) = self.end_s {
            if !end.is_finite() || end <= 0.0 {
                return Err("scenario: \"end_s\" must be finite and positive".into());
            }
        }
        let mut prev_at = 0.0f64;
        for (idx, step) in self.steps.iter().enumerate() {
            let label = step_label(idx, step.name.as_deref());
            if !step.at.is_finite() || step.at < 0.0 {
                return Err(format!("scenario: {label}: \"at\" must be finite and >= 0"));
            }
            if step.at < prev_at {
                return Err(format!(
                    "scenario: {label}: steps must be sorted by \"at\" ({} < {prev_at})",
                    step.at
                ));
            }
            prev_at = step.at;
            if let Some(end) = self.end_s {
                if step.at > end {
                    return Err(format!(
                        "scenario: {label}: \"at\" {} is past \"end_s\" {end}",
                        step.at
                    ));
                }
            }
            validate_action(&label, &step.action)?;
        }
        Ok(())
    }
}

fn validate_action(label: &str, action: &ScenarioAction) -> Result<(), String> {
    match action {
        ScenarioAction::SetFamily { .. } => Ok(()),
        ScenarioAction::SetArrivals { factor } => {
            if !factor.is_finite() || *factor <= 0.0 {
                return Err(format!("scenario: {label}: \"factor\" must be finite and > 0"));
            }
            Ok(())
        }
        ScenarioAction::RampArrivals { to_factor, over_s } => {
            if !to_factor.is_finite() || *to_factor <= 0.0 {
                return Err(format!("scenario: {label}: \"to_factor\" must be finite and > 0"));
            }
            if !over_s.is_finite() || *over_s < 0.0 {
                return Err(format!("scenario: {label}: \"over_s\" must be finite and >= 0"));
            }
            Ok(())
        }
        ScenarioAction::AddHosts { count, cores, mem_gb }
        | ScenarioAction::ResizeHosts { count, cores, mem_gb } => {
            if *count == 0 {
                return Err(format!("scenario: {label}: \"count\" must be >= 1"));
            }
            if !cores.is_finite() || *cores <= 0.0 || !mem_gb.is_finite() || *mem_gb <= 0.0 {
                return Err(format!(
                    "scenario: {label}: \"cores\" and \"mem_gb\" must be finite and > 0"
                ));
            }
            Ok(())
        }
        ScenarioAction::RemoveHosts { count } | ScenarioAction::RestoreHosts { count } => {
            if *count == 0 {
                return Err(format!("scenario: {label}: \"count\" must be >= 1"));
            }
            Ok(())
        }
        ScenarioAction::FaultWindow { duration_s, coverage, .. } => {
            if !duration_s.is_finite() || *duration_s <= 0.0 {
                return Err(format!("scenario: {label}: \"duration_s\" must be finite and > 0"));
            }
            if !(0.0..=1.0).contains(coverage) {
                return Err(format!("scenario: {label}: \"coverage\" must be in [0,1]"));
            }
            Ok(())
        }
    }
}

fn parse_step(idx: usize, raw: &Json) -> Result<ScenarioStep, String> {
    let name = raw.get("name").and_then(Json::as_str).map(str::to_string);
    let label = step_label(idx, name.as_deref());
    let at = raw
        .get("at")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("scenario: {label}: missing numeric \"at\""))?;
    let action_obj = raw
        .get("action")
        .ok_or_else(|| format!("scenario: {label}: missing \"action\""))?;
    let ty = action_obj
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| format!("scenario: {label}: action missing string \"type\""))?;
    let f64_field = |key: &str| -> Result<f64, String> {
        action_obj
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("scenario: {label}: action missing numeric \"{key}\""))
    };
    let count_field = || -> Result<usize, String> {
        action_obj
            .get("count")
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("scenario: {label}: action missing numeric \"count\""))
    };
    let action = match ty {
        "set-family" => {
            let fam = action_obj
                .get("family")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("scenario: {label}: action missing string \"family\""))?;
            let family = FamilyKind::parse(fam).ok_or_else(|| {
                format!("scenario: {label}: unknown workload family \"{fam}\"")
            })?;
            ScenarioAction::SetFamily { family }
        }
        "set-arrivals" => ScenarioAction::SetArrivals { factor: f64_field("factor")? },
        "ramp-arrivals" => ScenarioAction::RampArrivals {
            to_factor: f64_field("to_factor")?,
            over_s: f64_field("over_s")?,
        },
        "add-hosts" => ScenarioAction::AddHosts {
            count: count_field()?,
            cores: f64_field("cores")?,
            mem_gb: f64_field("mem_gb")?,
        },
        "remove-hosts" => ScenarioAction::RemoveHosts { count: count_field()? },
        "restore-hosts" => ScenarioAction::RestoreHosts { count: count_field()? },
        "resize-hosts" => ScenarioAction::ResizeHosts {
            count: count_field()?,
            cores: f64_field("cores")?,
            mem_gb: f64_field("mem_gb")?,
        },
        "fault-window" => {
            let kind_str = action_obj
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("scenario: {label}: action missing string \"kind\""))?;
            let kind = FaultWindowKind::parse(kind_str).ok_or_else(|| {
                format!("scenario: {label}: unknown fault-window kind \"{kind_str}\"")
            })?;
            ScenarioAction::FaultWindow {
                kind,
                duration_s: f64_field("duration_s")?,
                coverage: action_obj.get("coverage").and_then(Json::as_f64).unwrap_or(1.0),
                host: action_obj.get("host").and_then(Json::as_usize),
            }
        }
        other => {
            return Err(format!("scenario: {label}: unknown action type \"{other}\""));
        }
    };
    Ok(ScenarioStep { at, name, action })
}

/// The cluster half of one compiled step: hosts to bring up and hosts
/// to drain when the step's event fires. Generation-only steps compile
/// to an empty pair — the event still fires (it counts in
/// `RunReport::scenario_steps` and bounds quiet-stretch elision).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompiledStep {
    pub at: f64,
    /// Hosts returning to service at `at`.
    pub up: Vec<HostId>,
    /// Hosts draining at `at` (placements displaced and re-queued).
    pub down: Vec<HostId>,
}

/// The compiled, fully deterministic schedule for one run — the
/// scenario analogue of [`FaultPlan`]. `Default` (the no-scenario case)
/// is completely inert: no events primed, the base generator used
/// verbatim, the cluster built straight from the config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScenarioPlan {
    /// One entry per surviving scenario step (plus the `end_s` cleanup
    /// step when present), chronological.
    pub steps: Vec<CompiledStep>,
    /// Host classes the scenario appends to the configured cluster.
    /// These hosts exist from construction but start *down*; `add` /
    /// `resize` steps bring them up.
    pub added_classes: Vec<HostClass>,
    /// Generation-time demand timeline (family switches, rate changes).
    pub timeline: GenTimeline,
    /// Explicitly-timed fault windows, merged into the config-compiled
    /// [`FaultPlan`] before priming.
    pub extra_faults: FaultPlan,
}

impl ScenarioPlan {
    /// True when the plan changes nothing: the engine then behaves
    /// bit-for-bit as if the scenario module did not exist.
    pub fn is_inert(&self) -> bool {
        self.steps.is_empty()
            && self.added_classes.is_empty()
            && self.timeline.is_default()
            && self.extra_faults.is_empty()
    }

    /// Total number of hosts the engine's cluster will hold (configured
    /// hosts plus scenario-added classes).
    pub fn total_hosts(&self, cluster: &ClusterConfig) -> usize {
        cluster.total_hosts() + self.added_classes.iter().map(|c| c.count).sum::<usize>()
    }

    /// Build the engine's cluster for this plan: the configured shape
    /// plus any scenario-added classes, with every added host parked
    /// *down* until its step fires.
    pub fn build_cluster(&self, cfg: &ClusterConfig) -> Cluster {
        if self.added_classes.is_empty() {
            return Cluster::new(cfg);
        }
        let mut shaped = cfg.clone();
        shaped.extra_classes.extend(self.added_classes.iter().cloned());
        let mut cluster = Cluster::new(&shaped);
        for h in cfg.total_hosts()..cluster.len() {
            cluster.set_host_down(h);
        }
        cluster
    }

    /// Merge the scenario's explicitly-timed fault windows into the
    /// config-compiled plan. Scenario crash windows overlapping a base
    /// window for the same host are dropped deterministically (the base
    /// schedule wins — per-host windows must stay non-overlapping so the
    /// engine's crash/recover pairing holds). Telemetry and forecaster
    /// windows stack freely, as overlapping windows already do within
    /// `FaultPlan::compile`'s independent renewal streams.
    pub fn merge_faults_into(&self, base: &mut FaultPlan) {
        if self.extra_faults.is_empty() {
            return;
        }
        for w in &self.extra_faults.crashes {
            let overlaps = base
                .crashes
                .iter()
                .any(|b| b.host == w.host && w.crash_at < b.recover_at && b.crash_at < w.recover_at);
            if !overlaps {
                base.crashes.push(w.clone());
            }
        }
        base.telemetry.extend(self.extra_faults.telemetry.iter().cloned());
        base.forecast.extend(self.extra_faults.forecast.iter().cloned());
    }

    /// Compile a scenario over `[0, horizon_s]` for the configured
    /// cluster. Pure function of its arguments: same spec, config and
    /// seed ⇒ identical plan. `None` (or a step-less spec without
    /// `end_s`) compiles to the inert default. `min_window_s` floors
    /// fault-window lengths exactly as `FaultPlan::compile` does.
    pub fn compile(
        spec: Option<&ScenarioSpec>,
        cluster: &ClusterConfig,
        seed: u64,
        horizon_s: f64,
        min_window_s: f64,
    ) -> ScenarioPlan {
        let spec = match spec {
            Some(s) => s,
            None => return ScenarioPlan::default(),
        };
        let mut plan = ScenarioPlan::default();
        let base_hosts = cluster.total_hosts();
        let mut next_id = base_hosts;
        // Live-host tracking during compilation: base hosts start up,
        // scenario-added hosts down. `drained` is the restore stack
        // (most recently drained on top).
        let mut up: Vec<bool> = vec![true; base_hosts];
        let mut drained: Vec<HostId> = Vec::new();
        // Per-host end of the last scenario crash window, for intra-plan
        // non-overlap (the engine drops cross-plan overlaps on merge).
        let mut crash_end: Vec<f64> = vec![f64::NEG_INFINITY; base_hosts];
        let mut rng = Pcg::new(seed, SCENARIO_STREAM);
        let end_limit = spec.end_s.unwrap_or(horizon_s).min(horizon_s);
        let fault_on = faults::injection_enabled();
        for step in &spec.steps {
            if step.at > horizon_s {
                continue; // never fires; keep the plan minimal
            }
            let mut compiled = CompiledStep { at: step.at, up: Vec::new(), down: Vec::new() };
            match &step.action {
                ScenarioAction::SetFamily { family } => {
                    plan.timeline.push_family(step.at, *family);
                }
                ScenarioAction::SetArrivals { factor } => {
                    plan.timeline.push_set(step.at, *factor);
                }
                ScenarioAction::RampArrivals { to_factor, over_s } => {
                    plan.timeline.push_ramp(step.at, *to_factor, *over_s);
                }
                ScenarioAction::AddHosts { count, cores, mem_gb } => {
                    plan.added_classes.push(HostClass {
                        count: *count,
                        cores: *cores,
                        mem_gb: *mem_gb,
                    });
                    for _ in 0..*count {
                        compiled.up.push(next_id);
                        up.push(true);
                        crash_end.push(f64::NEG_INFINITY);
                        next_id += 1;
                    }
                }
                ScenarioAction::RemoveHosts { count } => {
                    drain(*count, &mut up, &mut drained, &mut compiled.down);
                }
                ScenarioAction::RestoreHosts { count } => {
                    for _ in 0..*count {
                        match drained.pop() {
                            Some(h) => {
                                up[h] = true;
                                compiled.up.push(h);
                            }
                            None => break,
                        }
                    }
                }
                ScenarioAction::ResizeHosts { count, cores, mem_gb } => {
                    // Drain-and-replace in one step: the old hosts go
                    // onto the restore stack, the replacements come up.
                    drain(*count, &mut up, &mut drained, &mut compiled.down);
                    plan.added_classes.push(HostClass {
                        count: *count,
                        cores: *cores,
                        mem_gb: *mem_gb,
                    });
                    for _ in 0..*count {
                        compiled.up.push(next_id);
                        up.push(true);
                        crash_end.push(f64::NEG_INFINITY);
                        next_id += 1;
                    }
                }
                ScenarioAction::FaultWindow { kind, duration_s, coverage, host } => {
                    // Draws happen unconditionally so ZOE_FAULTS=off
                    // changes only the fault plan, never later picks.
                    let salt = rng.next_u64();
                    let picked = host.unwrap_or_else(|| rng.index(base_hosts.max(1)));
                    let start = step.at;
                    let end = (start + duration_s.max(min_window_s)).min(end_limit);
                    if fault_on && end > start {
                        match kind {
                            FaultWindowKind::Dropout | FaultWindowKind::Corruption => {
                                plan.extra_faults.telemetry.push(TelemetryWindow {
                                    start,
                                    end,
                                    kind: if *kind == FaultWindowKind::Dropout {
                                        TelemetryFault::Dropout
                                    } else {
                                        TelemetryFault::Corruption
                                    },
                                    coverage: *coverage,
                                    salt,
                                });
                            }
                            FaultWindowKind::Forecast => {
                                plan.extra_faults
                                    .forecast
                                    .push(ForecastFaultWindow { start, end });
                            }
                            FaultWindowKind::Crash => {
                                // Only base-cluster hosts crash (added
                                // hosts have their own up/down steps),
                                // one window per host at a time.
                                if picked < base_hosts && start >= crash_end[picked] {
                                    crash_end[picked] = end;
                                    plan.extra_faults.crashes.push(CrashWindow {
                                        host: picked,
                                        crash_at: start,
                                        recover_at: end,
                                    });
                                }
                            }
                        }
                    }
                }
            }
            plan.steps.push(compiled);
        }
        // End semantics: restore the configured cluster shape. The
        // demand timeline needs no cleanup entry — generation consults
        // it only at submit times, and `end_s` caps the interesting
        // window by construction of the library scenarios.
        if let Some(end) = spec.end_s {
            if end <= horizon_s {
                let mut cleanup = CompiledStep { at: end, up: Vec::new(), down: Vec::new() };
                // Drained base hosts come back…
                for h in 0..base_hosts {
                    if !up[h] {
                        cleanup.up.push(h);
                    }
                }
                // …and every scenario-added host drains.
                for (h, live) in up.iter().enumerate().skip(base_hosts) {
                    if *live {
                        cleanup.down.push(h);
                    }
                }
                plan.timeline.push_family(end, FamilyKind::Baseline);
                plan.timeline.push_set(end, 1.0);
                plan.steps.push(cleanup);
            }
        }
        plan
    }
}

/// Drain `count` of the highest-id live hosts: flip them down, push them
/// onto the restore stack and record them in the step's `down` list. At
/// least one host always stays up.
fn drain(count: usize, up: &mut [bool], drained: &mut Vec<HostId>, down_out: &mut Vec<HostId>) {
    let live = up.iter().filter(|&&u| u).count();
    let take = count.min(live.saturating_sub(1));
    for _ in 0..take {
        if let Some(h) = up.iter().rposition(|&u| u) {
            up[h] = false;
            drained.push(h);
            down_out.push(h);
        }
    }
}

/// The in-tree scenario library, parsed and validated. Panics only if a
/// bundled file is broken — which `scripts/ci.sh` and the unit tests
/// below catch first.
pub fn library() -> Vec<ScenarioSpec> {
    LIBRARY_SOURCES
        .iter()
        .map(|src| ScenarioSpec::from_json(src).expect("bundled scenario invalid"))
        .collect()
}

/// Look up one library scenario by id.
pub fn library_spec(id: &str) -> Option<ScenarioSpec> {
    library().into_iter().find(|s| s.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> ScenarioSpec {
        ScenarioSpec::from_json(
            r#"{
              "version": 1, "id": "demo", "name": "Demo", "description": "d",
              "end_s": 7200,
              "steps": [
                {"at": 0, "action": {"type": "set-family", "family": "diurnal"}},
                {"at": 600, "name": "surge",
                 "action": {"type": "ramp-arrivals", "to_factor": 2.5, "over_s": 300}},
                {"at": 900, "action": {"type": "add-hosts", "count": 2, "cores": 8, "mem_gb": 24}},
                {"at": 1800, "action": {"type": "remove-hosts", "count": 1}},
                {"at": 2400, "action": {"type": "restore-hosts", "count": 1}},
                {"at": 3000, "action": {"type": "resize-hosts", "count": 1, "cores": 16, "mem_gb": 48}},
                {"at": 3600, "action": {"type": "fault-window", "kind": "dropout",
                                        "duration_s": 600, "coverage": 0.5}},
                {"at": 4200, "action": {"type": "fault-window", "kind": "crash",
                                        "duration_s": 600, "host": 0}}
              ]
            }"#,
        )
        .expect("demo spec parses")
    }

    #[test]
    fn parse_round_trip_covers_every_action() {
        let s = demo_spec();
        assert_eq!(s.id, "demo");
        assert_eq!(s.steps.len(), 8);
        assert_eq!(s.end_s, Some(7200.0));
        assert!(matches!(
            s.steps[0].action,
            ScenarioAction::SetFamily { family: FamilyKind::Diurnal }
        ));
        assert_eq!(s.steps[1].name.as_deref(), Some("surge"));
    }

    #[test]
    fn errors_name_the_offending_step() {
        let unsorted = r#"{"version":1,"id":"x","steps":[
          {"at": 100, "action": {"type": "set-arrivals", "factor": 2}},
          {"at": 50, "name": "late", "action": {"type": "set-arrivals", "factor": 1}}]}"#;
        let e = ScenarioSpec::from_json(unsorted).unwrap_err();
        assert!(e.contains("step 1 (\"late\")"), "{e}");
        assert!(e.contains("sorted"), "{e}");

        let unknown = r#"{"version":1,"id":"x","steps":[
          {"at": 0, "action": {"type": "warp-drive"}}]}"#;
        let e = ScenarioSpec::from_json(unknown).unwrap_err();
        assert!(e.contains("step 0"), "{e}");
        assert!(e.contains("warp-drive"), "{e}");

        let bad_version = r#"{"version":2,"id":"x","steps":[]}"#;
        let e = ScenarioSpec::from_json(bad_version).unwrap_err();
        assert!(e.contains("unsupported scenario version 2"), "{e}");

        let bad_factor = r#"{"version":1,"id":"x","steps":[
          {"at": 0, "action": {"type": "set-arrivals", "factor": 0}}]}"#;
        let e = ScenarioSpec::from_json(bad_factor).unwrap_err();
        assert!(e.contains("step 0") && e.contains("factor"), "{e}");

        let bad_family = r#"{"version":1,"id":"x","steps":[
          {"at": 0, "action": {"type": "set-family", "family": "mystery"}}]}"#;
        let e = ScenarioSpec::from_json(bad_family).unwrap_err();
        assert!(e.contains("step 0") && e.contains("mystery"), "{e}");
    }

    #[test]
    fn compile_none_or_empty_is_inert() {
        let cluster = ClusterConfig::uniform(4, 8.0, 16.0);
        let plan = ScenarioPlan::compile(None, &cluster, 42, 86_400.0, 60.0);
        assert!(plan.is_inert());
        assert_eq!(plan, ScenarioPlan::default());
        let empty = ScenarioSpec {
            id: "empty".into(),
            name: "Empty".into(),
            description: String::new(),
            end_s: None,
            steps: Vec::new(),
        };
        let plan = ScenarioPlan::compile(Some(&empty), &cluster, 42, 86_400.0, 60.0);
        assert!(plan.is_inert());
    }

    #[test]
    fn compile_is_deterministic_and_tracks_hosts() {
        let cluster = ClusterConfig::uniform(4, 8.0, 16.0);
        let spec = demo_spec();
        let a = ScenarioPlan::compile(Some(&spec), &cluster, 42, 86_400.0, 60.0);
        let b = ScenarioPlan::compile(Some(&spec), &cluster, 42, 86_400.0, 60.0);
        assert_eq!(a, b);
        assert!(!a.is_inert());
        // 8 steps + 1 cleanup
        assert_eq!(a.steps.len(), 9);
        // add-hosts (2) + resize-hosts (1) ⇒ two added classes, 3 hosts
        assert_eq!(a.added_classes.len(), 2);
        assert_eq!(a.total_hosts(&cluster), 7);
        // step 2 brings up the first added pair (ids 4, 5)
        assert_eq!(a.steps[2].up, vec![4, 5]);
        // remove drains the highest live id (5), restore brings it back
        assert_eq!(a.steps[3].down, vec![5]);
        assert_eq!(a.steps[4].up, vec![5]);
        // resize drains the new highest (5) and raises replacement id 6
        assert_eq!(a.steps[5].down, vec![5]);
        assert_eq!(a.steps[5].up, vec![6]);
        // fault windows landed in the extra plan
        assert_eq!(a.extra_faults.telemetry.len(), 1);
        assert_eq!(a.extra_faults.crashes.len(), 1);
        assert_eq!(a.extra_faults.crashes[0].host, 0);
        // cleanup restores the configured shape: drained base hosts up
        // (none here), added-and-live hosts down (ids 4 and 6; 5 was
        // drained by the resize)
        let cleanup = a.steps.last().unwrap();
        assert_eq!(cleanup.at, 7200.0);
        assert_eq!(cleanup.up, vec![5]);
        assert_eq!(cleanup.down, vec![4, 6]);
    }

    #[test]
    fn drain_never_empties_the_cluster() {
        let cluster = ClusterConfig::uniform(2, 8.0, 16.0);
        let spec = ScenarioSpec {
            id: "x".into(),
            name: "x".into(),
            description: String::new(),
            end_s: None,
            steps: vec![ScenarioStep {
                at: 10.0,
                name: None,
                action: ScenarioAction::RemoveHosts { count: 99 },
            }],
        };
        let plan = ScenarioPlan::compile(Some(&spec), &cluster, 1, 86_400.0, 60.0);
        assert_eq!(plan.steps[0].down, vec![1], "one host must stay up");
    }

    #[test]
    fn build_cluster_parks_added_hosts_down() {
        let cluster_cfg = ClusterConfig::uniform(3, 8.0, 16.0);
        let spec = demo_spec();
        let plan = ScenarioPlan::compile(Some(&spec), &cluster_cfg, 42, 86_400.0, 60.0);
        let cluster = plan.build_cluster(&cluster_cfg);
        assert_eq!(cluster.len(), plan.total_hosts(&cluster_cfg));
        for h in 0..3 {
            assert!(!cluster.is_down(h));
        }
        for h in 3..cluster.len() {
            assert!(cluster.is_down(h), "added host {h} must start down");
        }
    }

    #[test]
    fn library_parses_and_covers_every_family() {
        let lib = library();
        assert_eq!(lib.len(), LIBRARY_IDS.len());
        for (spec, id) in lib.iter().zip(LIBRARY_IDS) {
            assert_eq!(spec.id, id);
            assert!(!spec.steps.is_empty(), "{id} has no steps");
        }
        for id in LIBRARY_IDS {
            assert!(library_spec(id).is_some());
        }
        // each non-baseline family appears somewhere in the library
        for fam in [
            FamilyKind::Diurnal,
            FamilyKind::BurstyOnOff,
            FamilyKind::HeavyTail,
            FamilyKind::AntiForecast,
        ] {
            let used = library().iter().any(|s| {
                s.steps.iter().any(|st| {
                    matches!(st.action, ScenarioAction::SetFamily { family } if family == fam)
                })
            });
            assert!(used, "{} unused by the library", fam.name());
        }
    }

    #[test]
    fn steps_past_the_horizon_are_dropped() {
        let cluster = ClusterConfig::uniform(2, 8.0, 16.0);
        let spec = ScenarioSpec {
            id: "x".into(),
            name: "x".into(),
            description: String::new(),
            end_s: None,
            steps: vec![
                ScenarioStep {
                    at: 100.0,
                    name: None,
                    action: ScenarioAction::SetArrivals { factor: 2.0 },
                },
                ScenarioStep {
                    at: 1e9,
                    name: None,
                    action: ScenarioAction::SetArrivals { factor: 3.0 },
                },
            ],
        };
        let plan = ScenarioPlan::compile(Some(&spec), &cluster, 1, 86_400.0, 60.0);
        assert_eq!(plan.steps.len(), 1);
    }
}
