//! Sharded multi-coordinator federation: deterministic partitioning of
//! one simulated cluster into `N` coordinator shards, each owning a
//! contiguous sub-cluster and its own control-plane state (scheduler
//! queue, placer, monitor arena, shaper scratch — see
//! [`crate::sim::engine`]), glued together by a cross-shard
//! admission/overflow layer that stays bit-for-bit deterministic.
//!
//! ## Partition rule
//!
//! [`ShardPlan::new`] reuses the worker-pool chunk discipline
//! ([`crate::util::pool`]): `hosts` are split into `ceil(hosts / w)`
//! contiguous chunks of `chunk = ceil(hosts / w)` hosts where
//! `w = shards.clamp(1, hosts)`, so host `h` belongs to shard
//! `h / chunk` — a pure function of host id, independent of workload,
//! repeat, engine mode and `ZOE_WORKERS`. Requesting more shards than
//! hosts clamps (no empty shards); the last shard may be short.
//! Applications are assigned a **home shard** by
//! [`ShardPlan::home_of_app`] (`app_id % shards`) — also a pure
//! function of the id, so admission routing is reproducible by
//! construction.
//!
//! ## Admission and overflow probing
//!
//! Each shard's scheduler sees a [`FederatedPlacer`] wrapping the run's
//! configured placer. A placement probe first consults the home shard's
//! host range through [`Placer::select_in`]; on failure it probes the
//! remaining shards in deterministic wrap-around order (home+1, home+2,
//! … mod `N`), bounded by `federation.overflow_probes` foreign shards
//! (`0` = probe all). Placements landing outside the component's home
//! shard are counted by the engine as *overflow placements* in the run
//! metrics. With `N = 1` the wrapper delegates to the inner placer's
//! unrestricted [`Placer::select`] verbatim, which is how `shards = 1`
//! stays bit-identical to the monolithic control plane.
//!
//! ## Migration on sustained imbalance
//!
//! [`MigrationTracker`] watches per-shard allocation fractions
//! ([`crate::cluster::Cluster::allocation_fraction_in`]); when the
//! hottest and coldest shard differ by more than
//! `federation.migrate_imbalance` for `federation.migrate_sustain`
//! consecutive checks, it fires one deterministic migration decision
//! (hottest → coldest). Migration is off by default
//! (`migrate_interval_s = 0`), keeping the default federation purely
//! admission-time.

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::scheduler::Placer;
use crate::workload::{AppId, HostId};

/// Deterministic stable partition of `hosts` host ids into contiguous
/// shard ranges (see the module docs' partition rule).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    hosts: usize,
    chunk: usize,
    shards: usize,
}

impl ShardPlan {
    /// Partition `hosts` into at most `shards` contiguous ranges using
    /// the pool chunk discipline. `shards` is clamped to `[1, hosts]`
    /// (and to 1 when `hosts = 0`), then reduced further if the ceiling
    /// chunk size leaves trailing chunks empty — every shard in the
    /// resulting plan owns at least one host.
    pub fn new(hosts: usize, shards: usize) -> Self {
        let w = shards.max(1).min(hosts.max(1));
        // the pool chunk idiom: ceil(hosts / w) without div_ceil
        let chunk = {
            let q = hosts / w;
            if hosts % w == 0 {
                q
            } else {
                q + 1
            }
        }
        .max(1);
        let shards = {
            let q = hosts / chunk;
            if hosts % chunk == 0 {
                q
            } else {
                q + 1
            }
        }
        .max(1);
        ShardPlan { hosts, chunk, shards }
    }

    /// Number of (non-empty) shards in the plan.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Total hosts partitioned.
    pub fn hosts(&self) -> usize {
        self.hosts
    }

    /// Half-open host-id range `[lo, hi)` owned by shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        let lo = (s * self.chunk).min(self.hosts);
        let hi = ((s + 1) * self.chunk).min(self.hosts);
        (lo, hi)
    }

    /// Shard owning host `h`.
    pub fn shard_of_host(&self, h: HostId) -> usize {
        (h / self.chunk).min(self.shards.saturating_sub(1))
    }

    /// Home shard of application `a` (admission routing).
    pub fn home_of_app(&self, a: AppId) -> usize {
        a % self.shards
    }
}

/// Per-shard placement policy: home-shard probe first, then bounded
/// deterministic wrap-around overflow probing (see the module docs).
/// One `FederatedPlacer` is built per shard, wrapping the run's single
/// configured placer.
pub struct FederatedPlacer {
    inner: Arc<dyn Placer>,
    plan: ShardPlan,
    home: usize,
    /// Max foreign shards probed after the home shard; 0 = all.
    overflow_probes: usize,
}

impl FederatedPlacer {
    /// Wrap `inner` for the shard `home` of `plan`.
    pub fn new(inner: Arc<dyn Placer>, plan: ShardPlan, home: usize, overflow_probes: usize) -> Self {
        debug_assert!(home < plan.shards());
        FederatedPlacer { inner, plan, home, overflow_probes }
    }

    /// The shard this placer probes first.
    pub fn home(&self) -> usize {
        self.home
    }
}

impl Placer for FederatedPlacer {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn select(&self, cluster: &Cluster, cpus: f64, mem: f64) -> Option<HostId> {
        let n = self.plan.shards();
        if n == 1 {
            // verbatim delegation: shards = 1 is the monolithic placer,
            // bit for bit — no range query in the path
            return self.inner.select(cluster, cpus, mem);
        }
        let overflow =
            if self.overflow_probes == 0 { n - 1 } else { self.overflow_probes.min(n - 1) };
        for i in 0..=overflow {
            let s = (self.home + i) % n;
            let (lo, hi) = self.plan.range(s);
            if let Some(h) = self.inner.select_in(cluster, lo, hi, cpus, mem) {
                return Some(h);
            }
        }
        None
    }

    fn select_in(&self, cluster: &Cluster, lo: usize, hi: usize, cpus: f64, mem: f64) -> Option<HostId> {
        // already range-restricted by the caller: no further federation
        self.inner.select_in(cluster, lo, hi, cpus, mem)
    }
}

/// Sustained-imbalance detector driving optional cross-shard migration
/// (see the module docs). Purely deterministic: argmax/argmin tie-break
/// to the lowest shard index, and the streak resets both on firing and
/// whenever the imbalance dips below the threshold.
#[derive(Debug, Clone)]
pub struct MigrationTracker {
    imbalance: f64,
    sustain: u32,
    streak: u32,
}

impl MigrationTracker {
    /// Fire after `sustain` consecutive observations whose max−min
    /// shard load exceeds `imbalance`.
    pub fn new(imbalance: f64, sustain: u32) -> Self {
        MigrationTracker { imbalance, sustain: sustain.max(1), streak: 0 }
    }

    /// Feed one observation of per-shard loads (allocation fractions).
    /// Returns `Some((hottest, coldest))` when the imbalance has been
    /// sustained — a migration should re-home one app from `hottest`
    /// to `coldest` — else `None`.
    pub fn observe(&mut self, loads: &[f64]) -> Option<(usize, usize)> {
        if loads.len() < 2 {
            self.streak = 0;
            return None;
        }
        let (mut hot, mut cold) = (0usize, 0usize);
        for (s, &v) in loads.iter().enumerate() {
            if v > loads[hot] {
                hot = s;
            }
            if v < loads[cold] {
                cold = s;
            }
        }
        if loads[hot] - loads[cold] > self.imbalance {
            self.streak += 1;
            if self.streak >= self.sustain {
                self.streak = 0;
                return Some((hot, cold));
            }
        } else {
            self.streak = 0;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::scheduler::{FirstFitPlacer, WorstFitPlacer};

    #[test]
    fn shard_plan_partitions_exactly_with_no_empty_shards() {
        for hosts in [1usize, 2, 3, 7, 10, 16, 250] {
            for shards in [1usize, 2, 3, 4, 8, 300] {
                let p = ShardPlan::new(hosts, shards);
                assert!(p.shards() >= 1 && p.shards() <= hosts, "hosts={hosts} shards={shards}");
                let mut covered = 0usize;
                for s in 0..p.shards() {
                    let (lo, hi) = p.range(s);
                    assert!(lo < hi, "empty shard {s} for hosts={hosts} shards={shards}");
                    assert_eq!(lo, covered, "non-contiguous partition");
                    for h in lo..hi {
                        assert_eq!(p.shard_of_host(h), s);
                    }
                    covered = hi;
                }
                assert_eq!(covered, hosts, "partition must cover every host exactly once");
            }
        }
    }

    #[test]
    fn shard_plan_matches_pool_chunking() {
        // 10 hosts over 4 shards: ceil(10/4)=3 → [0,3) [3,6) [6,9) [9,10)
        let p = ShardPlan::new(10, 4);
        assert_eq!(p.shards(), 4);
        assert_eq!(p.range(0), (0, 3));
        assert_eq!(p.range(3), (9, 10));
        // 4 hosts over 8 shards clamps to 4 singleton shards
        let p = ShardPlan::new(4, 8);
        assert_eq!(p.shards(), 4);
        assert_eq!(p.range(2), (2, 3));
        // 8 hosts over 3 shards: chunk 3 → shards [0,3) [3,6) [6,8)
        let p = ShardPlan::new(8, 3);
        assert_eq!(p.shards(), 3);
        assert_eq!(p.range(2), (6, 8));
        // degenerate: zero hosts still yields one (empty-range) shard
        let p = ShardPlan::new(0, 4);
        assert_eq!(p.shards(), 1);
        assert_eq!(p.range(0), (0, 0));
    }

    #[test]
    fn home_of_app_round_robins_over_shards() {
        let p = ShardPlan::new(8, 4);
        for a in 0..16usize {
            assert_eq!(p.home_of_app(a), a % 4);
        }
    }

    #[test]
    fn federated_placer_prefers_home_then_probes_wrap_around() {
        // 4 hosts, 2 shards of 2; home = shard 1 (hosts 2, 3)
        let mut c = Cluster::new(&ClusterConfig::uniform(4, 8.0, 32.0));
        let plan = ShardPlan::new(4, 2);
        let p = FederatedPlacer::new(Arc::new(WorstFitPlacer), plan.clone(), 1, 0);
        // home shard has room: stays home (worst-fit ties → highest id)
        assert_eq!(p.select(&c, 1.0, 1.0), Some(3));
        // fill the home shard: overflow into shard 0
        assert!(c.place(0, 2, 8.0, 32.0, 0.0));
        assert!(c.place(1, 3, 8.0, 32.0, 0.0));
        assert_eq!(p.select(&c, 1.0, 1.0), Some(1));
        // nothing anywhere: None
        assert!(c.place(2, 0, 8.0, 32.0, 0.0));
        assert!(c.place(3, 1, 8.0, 32.0, 0.0));
        assert_eq!(p.select(&c, 1.0, 1.0), None);
    }

    #[test]
    fn overflow_probe_bound_limits_foreign_shards() {
        // 4 singleton shards; only shard 3 (host 3) has room
        let mut c = Cluster::new(&ClusterConfig::uniform(4, 8.0, 32.0));
        for h in 0..3usize {
            assert!(c.place(h, h, 8.0, 32.0, 0.0));
        }
        let plan = ShardPlan::new(4, 4);
        // home 0, one foreign probe: reaches only shard 1 → None
        let bounded = FederatedPlacer::new(Arc::new(FirstFitPlacer), plan.clone(), 0, 1);
        assert_eq!(bounded.select(&c, 1.0, 1.0), None);
        // home 0, unbounded: wraps to shard 3
        let unbounded = FederatedPlacer::new(Arc::new(FirstFitPlacer), plan.clone(), 0, 0);
        assert_eq!(unbounded.select(&c, 1.0, 1.0), Some(3));
        // home 2, one foreign probe: shard 3 is the first probe → hit
        let near = FederatedPlacer::new(Arc::new(FirstFitPlacer), plan, 2, 1);
        assert_eq!(near.select(&c, 1.0, 1.0), Some(3));
    }

    #[test]
    fn single_shard_delegates_to_the_unrestricted_placer() {
        let c = Cluster::new(&ClusterConfig::uniform(3, 8.0, 32.0));
        let plan = ShardPlan::new(3, 1);
        let p = FederatedPlacer::new(Arc::new(WorstFitPlacer), plan, 0, 0);
        assert_eq!(p.select(&c, 1.0, 1.0), WorstFitPlacer.select(&c, 1.0, 1.0));
    }

    #[test]
    fn migration_tracker_requires_sustained_imbalance() {
        let mut t = MigrationTracker::new(0.25, 3);
        let hot = [0.9, 0.1, 0.5];
        assert_eq!(t.observe(&hot), None);
        assert_eq!(t.observe(&hot), None);
        assert_eq!(t.observe(&hot), Some((0, 1)), "third consecutive breach fires");
        assert_eq!(t.observe(&hot), None, "streak resets after firing");
        // a calm observation resets the streak
        assert_eq!(t.observe(&hot), None);
        assert_eq!(t.observe(&[0.5, 0.5, 0.5]), None);
        assert_eq!(t.observe(&hot), None);
        assert_eq!(t.observe(&hot), None);
        assert_eq!(t.observe(&hot), Some((0, 1)));
        // single-shard loads never fire
        let mut one = MigrationTracker::new(0.0, 1);
        assert_eq!(one.observe(&[1.0]), None);
    }
}
