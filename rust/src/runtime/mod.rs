//! PJRT runtime bridge: load AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! This is the only place the crate touches the `xla` FFI. The interchange
//! format is HLO *text* (never serialized protos): jax >= 0.5 emits protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. See /opt/xla-example/README.md.
//!
//! The `xla` bridge only exists in the offline build image, so the real
//! `Runtime`/`Executable` are compiled under `--features pjrt`. Default
//! builds get an uninhabited stub whose constructors return a clear
//! "PJRT support not compiled in" error — every PJRT-dependent test and
//! bench already treats a `Runtime` construction failure as "skip", so
//! tier-1 stays green on a bare Rust toolchain while the manifest layer
//! (artifact discovery) remains fully functional and tested.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::KernelKind;
use crate::util::json::Json;

/// Description of one AOT artifact from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub kind: KernelKind,
    pub history: usize,
    pub n_train: usize,
    pub pattern_dim: usize,
    pub batch: usize,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            let gets = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))?
                    .to_string())
            };
            let getn = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let kind = KernelKind::parse(&gets("kind")?)
                .ok_or_else(|| anyhow!("bad kernel kind in manifest"))?;
            artifacts.push(ArtifactInfo {
                name: gets("name")?,
                file: gets("file")?,
                kind,
                history: getn("history")?,
                n_train: getn("n_train")?,
                pattern_dim: getn("pattern_dim")?,
                batch: getn("batch")?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Find an artifact by (kernel kind, history, batch).
    pub fn find(&self, kind: KernelKind, history: usize, batch: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.history == history && a.batch == batch)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }
}

/// The default artifacts directory: `$ZOE_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("ZOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::*;
    use anyhow::bail;
    use std::collections::HashMap;
    use std::sync::Mutex;

    /// A compiled executable plus its artifact metadata.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub info: ArtifactInfo,
    }

    /// PJRT CPU client wrapper with an executable cache keyed by artifact
    /// name.
    ///
    /// Compilation is expensive (tens of ms); the coordinator compiles
    /// each artifact once and reuses it for every forecast call on the
    /// hot path.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Create a CPU PJRT client and load the artifact manifest.
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let manifest = Manifest::load(artifact_dir)?;
            Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
        }

        /// Create from the default artifact directory.
        pub fn from_default_dir() -> Result<Runtime> {
            Self::new(default_artifact_dir())
        }

        /// The manifest describing available artifacts.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an artifact (cached).
        pub fn load(
            &self,
            kind: KernelKind,
            history: usize,
            batch: usize,
        ) -> Result<std::sync::Arc<Executable>> {
            let info = self
                .manifest
                .find(kind, history, batch)
                .ok_or_else(|| {
                    anyhow!(
                        "no artifact for kind={} h={history} b={batch}; run `make artifacts`",
                        kind.name()
                    )
                })?
                .clone();
            {
                let cache = self.cache.lock().unwrap();
                if let Some(e) = cache.get(&info.name) {
                    return Ok(e.clone());
                }
            }
            let path = self.manifest.path_of(&info);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", info.name))?;
            let arc = std::sync::Arc::new(Executable { exe, info: info.clone() });
            self.cache.lock().unwrap().insert(info.name, arc.clone());
            Ok(arc)
        }

        /// Execute a compiled GP artifact.
        ///
        /// Inputs are flattened f32 buffers in artifact order:
        /// `x_train, y_train, x_query, lengthscale, noise` (shapes per
        /// `Executable::info`). Output is the flattened tuple
        /// `(mean(s), var(s), lml(s))` — scalars for batch=1, `(batch,)`
        /// vectors otherwise.
        pub fn run_gp(&self, exe: &Executable, inp: &GpInputs<'_>) -> Result<GpOutputs> {
            let info = &exe.info;
            let (n, p, b) = (info.n_train, info.pattern_dim, info.batch);
            if inp.x_train.len() != b * n * p
                || inp.y_train.len() != b * n
                || inp.x_query.len() != b * p
                || inp.lengthscale.len() != b
                || inp.noise.len() != b
            {
                bail!(
                    "gp input shape mismatch for {} (b={b}, n={n}, p={p}): got x={} y={} q={} ls={} nz={}",
                    info.name,
                    inp.x_train.len(),
                    inp.y_train.len(),
                    inp.x_query.len(),
                    inp.lengthscale.len(),
                    inp.noise.len()
                );
            }
            let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
                Ok(xla::Literal::vec1(data).reshape(dims)?)
            };
            let (xt, yt, xq, ls, nz) = if b == 1 {
                (
                    lit(inp.x_train, &[n as i64, p as i64])?,
                    lit(inp.y_train, &[n as i64])?,
                    lit(inp.x_query, &[p as i64])?,
                    xla::Literal::vec1(inp.lengthscale).reshape(&[])?,
                    xla::Literal::vec1(inp.noise).reshape(&[])?,
                )
            } else {
                (
                    lit(inp.x_train, &[b as i64, n as i64, p as i64])?,
                    lit(inp.y_train, &[b as i64, n as i64])?,
                    lit(inp.x_query, &[b as i64, p as i64])?,
                    lit(inp.lengthscale, &[b as i64])?,
                    lit(inp.noise, &[b as i64])?,
                )
            };
            let result = exe.exe.execute::<xla::Literal>(&[xt, yt, xq, ls, nz])?[0][0]
                .to_literal_sync()?;
            let (m, v, l) = result.to_tuple3()?;
            Ok(GpOutputs {
                means: m.to_vec::<f32>()?,
                vars: v.to_vec::<f32>()?,
                lmls: l.to_vec::<f32>()?,
            })
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::*;
    use anyhow::bail;
    use std::convert::Infallible;

    /// Stub executable: uninhabited without the `pjrt` feature.
    pub struct Executable {
        pub info: ArtifactInfo,
        #[allow(dead_code)]
        _never: Infallible,
    }

    /// Stub runtime: constructors always fail with an actionable message,
    /// so PJRT-dependent tests/benches skip and the native GP path is
    /// used instead. The type is uninhabited — the methods below exist
    /// only to keep callers type-checking.
    pub struct Runtime {
        _never: Infallible,
    }

    impl Runtime {
        /// Always fails: reports missing artifacts first (the more
        /// actionable error), then the missing feature.
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
            let manifest = Manifest::load(artifact_dir)?;
            bail!(
                "PJRT support not compiled in (artifacts found at {:?}); \
                 rebuild with `--features pjrt` in the XLA-enabled image to \
                 run the AOT path — the native GP forecaster is unaffected",
                manifest.dir
            )
        }

        /// Create from the default artifact directory (always fails; see
        /// [`Runtime::new`]).
        pub fn from_default_dir() -> Result<Runtime> {
            Self::new(default_artifact_dir())
        }

        /// The manifest describing available artifacts.
        pub fn manifest(&self) -> &Manifest {
            match self._never {}
        }

        /// PJRT platform name.
        pub fn platform(&self) -> String {
            match self._never {}
        }

        /// Load + compile an artifact.
        pub fn load(
            &self,
            _kind: KernelKind,
            _history: usize,
            _batch: usize,
        ) -> Result<std::sync::Arc<Executable>> {
            match self._never {}
        }

        /// Execute a compiled GP artifact.
        pub fn run_gp(&self, _exe: &Executable, _inp: &GpInputs<'_>) -> Result<GpOutputs> {
            match self._never {}
        }
    }
}

pub use backend::{Executable, Runtime};

/// Borrowed, flattened inputs for one GP artifact execution.
pub struct GpInputs<'a> {
    pub x_train: &'a [f32],
    pub y_train: &'a [f32],
    pub x_query: &'a [f32],
    pub lengthscale: &'a [f32],
    pub noise: &'a [f32],
}

/// Flattened outputs of one GP artifact execution.
#[derive(Debug, Clone)]
pub struct GpOutputs {
    pub means: Vec<f32>,
    pub vars: Vec<f32>,
    pub lmls: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_missing_is_clear_error() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature_when_artifacts_exist() {
        // with a valid manifest on disk, the stub must point at the
        // missing `pjrt` feature rather than at the artifacts
        let dir = std::env::temp_dir().join("zoe_stub_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
        let err = Runtime::new(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        let _ = std::fs::remove_dir(&dir);
    }
}
