//! Deterministic fault injection: compiles [`crate::config::FaultConfig`]
//! into a seeded [`FaultPlan`] — host crash/recover windows, telemetry
//! dropout/corruption windows and forecaster fault windows — that the
//! engine primes onto the event queue alongside arrivals.
//!
//! Everything here is a pure function of `(config, seed, horizon)`:
//! window times come from per-purpose [`Pcg`] streams forked off the run
//! seed, and per-window component coverage is a seeded hash of the
//! component id, so a faulted run is exactly as reproducible as a
//! healthy one — bit-identical across `ZOE_WORKERS`/`ZOE_LANES` sweeps,
//! both engine modes, and repeated runs. An inert config (all rates
//! zero) compiles to an *empty* plan: the engine then pushes no fault
//! events and touches no fault state, keeping its `RunReport` bit-for-bit
//! identical to a build without this module (tests/fault_determinism.rs).
//!
//! The graceful-degradation half lives with the subsystems it protects:
//! host up/down state in `cluster`, the non-finite sample guard in
//! `monitor`, the quarantine ladder in `forecast::quarantine`, and the
//! retry/backoff pipeline in `sim::engine` (which also owns the
//! [`backoff_delay`] schedule defined here).

use crate::config::FaultConfig;
use crate::util::rng::Pcg;
use crate::workload::{ComponentId, HostId};

/// Stream id separating fault-plan draws from every other consumer of
/// the run seed (workload generation uses the seed directly).
const FAULT_STREAM: u64 = 0xFA_17;

/// One injected host outage: the host crashes at `crash_at` (every
/// placement on it is killed) and rejoins the capacity indexes at
/// `recover_at`. Windows for the same host never overlap.
#[derive(Debug, Clone, PartialEq)]
pub struct CrashWindow {
    pub host: HostId,
    pub crash_at: f64,
    pub recover_at: f64,
}

/// What a telemetry fault window does to covered components' samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TelemetryFault {
    /// Samples are silently lost: the monitor records nothing and the
    /// series goes stale.
    Dropout,
    /// Samples arrive non-finite (NaN): `Monitor::record`'s guard drops
    /// them — same staleness, plus the once-per-component error log and
    /// the dropped-sample counter.
    Corruption,
}

/// A telemetry fault window: between `start` and `end`, components
/// covered by the seeded hash lose their monitor samples.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryWindow {
    pub start: f64,
    pub end: f64,
    pub kind: TelemetryFault,
    /// Fraction of components covered, in [0,1].
    pub coverage: f64,
    /// Per-window hash salt: which components are covered differs from
    /// window to window but is fixed within one.
    pub salt: u64,
}

impl TelemetryWindow {
    /// Is component `c` covered by this window?
    pub fn covers(&self, c: ComponentId) -> bool {
        covered(c as u64, self.salt, self.coverage)
    }
}

/// A forecaster fault window: between `start` and `end`, every model
/// forecast comes back non-finite (simulated numerical failure),
/// driving covered series onto the quarantine ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastFaultWindow {
    pub start: f64,
    pub end: f64,
}

/// The compiled, fully deterministic fault schedule for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Host outages, host-major then chronological per host.
    pub crashes: Vec<CrashWindow>,
    /// Telemetry windows, dropouts first then corruptions, each
    /// chronological and non-overlapping within its kind.
    pub telemetry: Vec<TelemetryWindow>,
    /// Forecaster fault windows, chronological, non-overlapping.
    pub forecast: Vec<ForecastFaultWindow>,
}

impl FaultPlan {
    /// No injected faults at all — the engine skips the fault layer
    /// entirely (no events, no state, bit-identical reports).
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.telemetry.is_empty() && self.forecast.is_empty()
    }

    /// Total number of events this plan will prime (each window
    /// contributes its start and end).
    pub fn event_count(&self) -> usize {
        2 * (self.crashes.len() + self.telemetry.len() + self.forecast.len())
    }

    /// Compile the config into a concrete schedule over `[0, horizon_s]`
    /// for a cluster of `hosts` machines. `min_window_s` floors every
    /// window length (the engine passes the monitor interval, so no
    /// window closes inside the tick that opened it). Returns the empty
    /// plan for an inert config or when `ZOE_FAULTS=off`.
    pub fn compile(
        cfg: &FaultConfig,
        hosts: usize,
        seed: u64,
        horizon_s: f64,
        min_window_s: f64,
    ) -> FaultPlan {
        if cfg.is_inert() || !injection_enabled() || horizon_s <= 0.0 {
            return FaultPlan::default();
        }
        let mut root = Pcg::new(seed, FAULT_STREAM);
        let mut plan = FaultPlan::default();
        // Host crashes: an independent renewal process per host, so one
        // host's schedule never perturbs another's.
        if cfg.crash_rate_per_host_day > 0.0 {
            let gap_mean = 86_400.0 / cfg.crash_rate_per_host_day;
            let mut crash_rng = root.fork(1);
            for host in 0..hosts {
                let mut rng = crash_rng.fork(host as u64);
                let mut t = rng.exponential(gap_mean);
                while t < horizon_s {
                    let downtime = rng.exponential(cfg.crash_downtime_mean_s).max(min_window_s);
                    plan.crashes.push(CrashWindow {
                        host,
                        crash_at: t,
                        recover_at: t + downtime,
                    });
                    t += downtime + rng.exponential(gap_mean).max(min_window_s);
                }
            }
        }
        let mut telemetry_windows = |rng: &mut Pcg,
                                     rate_per_day: f64,
                                     duration_mean: f64,
                                     kind: TelemetryFault,
                                     out: &mut Vec<TelemetryWindow>| {
            if rate_per_day <= 0.0 {
                return;
            }
            let gap_mean = 86_400.0 / rate_per_day;
            let mut t = rng.exponential(gap_mean);
            while t < horizon_s {
                let dur = rng.exponential(duration_mean).max(min_window_s);
                out.push(TelemetryWindow {
                    start: t,
                    end: t + dur,
                    kind,
                    coverage: cfg.dropout_coverage,
                    salt: rng.next_u64(),
                });
                t += dur + rng.exponential(gap_mean).max(min_window_s);
            }
        };
        let mut drop_rng = root.fork(2);
        telemetry_windows(
            &mut drop_rng,
            cfg.dropout_rate_per_day,
            cfg.dropout_duration_mean_s,
            TelemetryFault::Dropout,
            &mut plan.telemetry,
        );
        let mut corrupt_rng = root.fork(3);
        telemetry_windows(
            &mut corrupt_rng,
            cfg.corruption_rate_per_day,
            cfg.corruption_duration_mean_s,
            TelemetryFault::Corruption,
            &mut plan.telemetry,
        );
        if cfg.forecast_fault_rate_per_day > 0.0 {
            let gap_mean = 86_400.0 / cfg.forecast_fault_rate_per_day;
            let mut rng = root.fork(4);
            let mut t = rng.exponential(gap_mean);
            while t < horizon_s {
                let dur = rng.exponential(cfg.forecast_fault_duration_mean_s).max(min_window_s);
                plan.forecast.push(ForecastFaultWindow { start: t, end: t + dur });
                t += dur + rng.exponential(gap_mean).max(min_window_s);
            }
        }
        plan
    }
}

/// Deterministic exponential backoff with seeded jitter for attempt
/// `attempt` (1-based) of re-enqueueing crash-displaced application
/// `app`. Derived from `(seed, app, attempt)` alone — independent of
/// event interleaving, worker count and engine mode — so retry times
/// are as reproducible as the rest of the run.
pub fn backoff_delay(cfg: &FaultConfig, seed: u64, app: usize, attempt: u32) -> f64 {
    let exp = attempt.saturating_sub(1).min(32);
    let base = (cfg.retry_base_delay_s * f64::from(1u32 << exp.min(30)))
        .min(cfg.retry_max_delay_s);
    let mut rng = Pcg::new(
        seed ^ FAULT_STREAM.rotate_left(32),
        ((app as u64) << 8) | u64::from(attempt & 0xFF),
    );
    let jitter = 1.0 + cfg.retry_jitter * (2.0 * rng.f64() - 1.0);
    base * jitter
}

/// `ZOE_FAULTS=off|0|false` force-disables injection (the compiled plan
/// is empty) regardless of the config — the A/B switch for comparing a
/// chaos config against its healthy twin without editing it. Public so
/// the scenario compiler honors the same switch for its fault windows.
pub fn injection_enabled() -> bool {
    !crate::util::env::is_off("ZOE_FAULTS", &[])
}

/// Seeded membership hash: maps `x` (a component id or series key) under
/// `salt` to a uniform draw in [0,1) and compares against `coverage`.
/// SplitMix64 finalizer — avalanche is what matters here, not sequence
/// quality, since each (x, salt) pair is hashed exactly once.
fn covered(x: u64, salt: u64, coverage: f64) -> bool {
    let mut z = (x ^ salt).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < coverage
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaos_cfg() -> FaultConfig {
        FaultConfig {
            crash_rate_per_host_day: 2.0,
            dropout_rate_per_day: 6.0,
            corruption_rate_per_day: 3.0,
            forecast_fault_rate_per_day: 2.0,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn inert_config_compiles_to_empty_plan() {
        let plan = FaultPlan::compile(&FaultConfig::default(), 8, 42, 86_400.0, 60.0);
        assert!(plan.is_empty());
        assert_eq!(plan.event_count(), 0);
    }

    #[test]
    fn compile_is_deterministic_in_the_seed() {
        let cfg = chaos_cfg();
        let a = FaultPlan::compile(&cfg, 8, 42, 86_400.0, 60.0);
        let b = FaultPlan::compile(&cfg, 8, 42, 86_400.0, 60.0);
        assert_eq!(a, b, "same seed must give the identical plan");
        assert!(!a.is_empty());
        let c = FaultPlan::compile(&cfg, 8, 43, 86_400.0, 60.0);
        assert_ne!(a, c, "different seeds must diverge");
    }

    #[test]
    fn windows_are_well_formed() {
        let cfg = chaos_cfg();
        let horizon = 7.0 * 86_400.0;
        let plan = FaultPlan::compile(&cfg, 6, 7, horizon, 60.0);
        for w in &plan.crashes {
            assert!(w.host < 6);
            assert!(w.crash_at >= 0.0 && w.crash_at < horizon);
            assert!(w.recover_at >= w.crash_at + 60.0, "downtime floored at a tick");
        }
        // per-host crash windows never overlap
        for h in 0..6 {
            let mut last_end = f64::NEG_INFINITY;
            for w in plan.crashes.iter().filter(|w| w.host == h) {
                assert!(w.crash_at > last_end, "host {h} windows overlap");
                last_end = w.recover_at;
            }
        }
        for w in &plan.telemetry {
            assert!(w.start >= 0.0 && w.start < horizon);
            assert!(w.end >= w.start + 60.0);
            assert!((0.0..=1.0).contains(&w.coverage));
        }
        for w in &plan.forecast {
            assert!(w.start >= 0.0 && w.start < horizon);
            assert!(w.end >= w.start + 60.0);
        }
        assert_eq!(
            plan.event_count(),
            2 * (plan.crashes.len() + plan.telemetry.len() + plan.forecast.len())
        );
    }

    #[test]
    fn coverage_hash_respects_bounds_and_rate() {
        let all = TelemetryWindow {
            start: 0.0,
            end: 1.0,
            kind: TelemetryFault::Dropout,
            coverage: 1.0,
            salt: 99,
        };
        let none = TelemetryWindow { coverage: 0.0, ..all.clone() };
        let half = TelemetryWindow { coverage: 0.5, ..all.clone() };
        let n = 10_000usize;
        let hit = (0..n).filter(|&c| half.covers(c)).count();
        for c in 0..n {
            assert!(all.covers(c));
            assert!(!none.covers(c));
        }
        let frac = hit as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.03, "coverage 0.5 hit {frac}");
        // membership is stable per window but differs across salts
        let other = TelemetryWindow { salt: 100, ..half.clone() };
        let differs = (0..n).filter(|&c| half.covers(c) != other.covers(c)).count();
        assert!(differs > n / 4, "salts must reshuffle coverage ({differs} differ)");
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let cfg = FaultConfig { retry_jitter: 0.5, ..FaultConfig::default() };
        let d1 = backoff_delay(&cfg, 42, 7, 1);
        let d5 = backoff_delay(&cfg, 42, 7, 5);
        assert!(d1 >= cfg.retry_base_delay_s * 0.5 && d1 <= cfg.retry_base_delay_s * 1.5);
        assert!(d5 > d1, "backoff must grow with attempts ({d1} vs {d5})");
        // the cap holds even at absurd attempt counts (no overflow)
        let dmax = backoff_delay(&cfg, 42, 7, 200);
        assert!(dmax <= cfg.retry_max_delay_s * 1.5);
        assert!(dmax.is_finite());
        // deterministic: same inputs, same delay; inputs matter
        assert_eq!(backoff_delay(&cfg, 42, 7, 3), backoff_delay(&cfg, 42, 7, 3));
        assert_ne!(backoff_delay(&cfg, 42, 7, 3), backoff_delay(&cfg, 42, 8, 3));
        assert_ne!(backoff_delay(&cfg, 42, 7, 3), backoff_delay(&cfg, 43, 7, 3));
    }

    #[test]
    fn zero_horizon_compiles_empty() {
        let plan = FaultPlan::compile(&chaos_cfg(), 4, 42, 0.0, 60.0);
        assert!(plan.is_empty());
    }
}
