//! The safe-guard buffer β (Eq. 9): `β = K1·R + K2·V`.
//!
//! * `K1·R` — static floor, a fraction of the original reservation that is
//!   always granted (K1 = 100% degenerates to the baseline).
//! * `K2·V` — dynamic term driven by the forecaster's uncertainty. The
//!   paper sweeps K2 ∈ {0,1,2,3}, describing the values as bands around
//!   the predictive mean "according to the three-sigma rule" — i.e. K2
//!   multiplies the predictive *standard deviation* σ; we follow that
//!   reading (σ has the units of the resource, variance does not).

use crate::forecast::Forecast;

/// β buffer in utilization-fraction units for a component with a given
/// forecast. `k1` is the static fraction of the reservation, `k2` the
/// sigma multiplier.
pub fn beta_fraction(forecast: &Forecast, k1: f64, k2: f64) -> f64 {
    k1 + k2 * forecast.std()
}

/// Desired allocation fraction: predicted (peak) demand plus β, clamped to
/// [floor, 1.0] of the reservation. The floor prevents zero allocations
/// on confident zero forecasts (a process always needs some memory).
pub fn desired_fraction(forecast: &Forecast, k1: f64, k2: f64) -> f64 {
    const FLOOR: f64 = 0.02;
    (forecast.mean + beta_fraction(forecast, k1, k2)).clamp(FLOOR, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k1_is_static_floor() {
        let f = Forecast { mean: 0.3, var: 0.0 };
        assert!((desired_fraction(&f, 0.05, 3.0) - 0.35).abs() < 1e-12);
        assert!((desired_fraction(&f, 0.0, 0.0) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn k1_100_percent_degenerates_to_reservation() {
        // K1=1.0: mean + 1.0 >= 1.0 always -> full reservation (baseline)
        for mean in [0.0, 0.3, 0.9] {
            let f = Forecast { mean, var: 0.2 };
            assert_eq!(desired_fraction(&f, 1.0, 0.0), 1.0);
        }
    }

    #[test]
    fn k2_scales_with_uncertainty() {
        let lo = Forecast { mean: 0.3, var: 0.0001 };
        let hi = Forecast { mean: 0.3, var: 0.09 };
        let d_lo = desired_fraction(&lo, 0.0, 2.0);
        let d_hi = desired_fraction(&hi, 0.0, 2.0);
        assert!(d_hi > d_lo);
        assert!((d_hi - (0.3 + 2.0 * 0.3)).abs() < 1e-12);
    }

    #[test]
    fn clamped_to_reservation_and_floor() {
        let f = Forecast { mean: 2.0, var: 1.0 };
        assert_eq!(desired_fraction(&f, 0.5, 3.0), 1.0);
        let g = Forecast { mean: -1.0, var: 0.0 };
        assert_eq!(desired_fraction(&g, 0.0, 0.0), 0.02);
    }
}
