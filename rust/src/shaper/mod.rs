//! The resource shaper (§3.2): the paper's contribution. Adjusts every
//! running component's allocation toward its forecast utilization plus a
//! safe-guard buffer β (Eq. 9), and decides preemption:
//!
//! * **Baseline** — never shapes; allocation stays at reservation.
//! * **Optimistic** — redeems slack and grows allocations only where room
//!   exists, *without* taking explicit action on contention: when demand
//!   collides, the "OS" OOM-kills at monitor time ([62]-style).
//! * **Pessimistic** — Algorithm 1: recomputes a feasible allocation in
//!   scheduler-priority order, fully preempting applications whose core
//!   components no longer fit and partially preempting elastic components
//!   (youngest first), then resizes the survivors.
//!
//! The planner runs every shaping tick, so its hot form is [`plan_into`]:
//! per-host free/trial arrays, sort keys and the output action lists all
//! live in caller-owned scratch ([`PlanScratch`] + [`ShapeActions`])
//! reused across ticks — zero allocations once warm. Every capacity
//! comparison uses the unified `cluster::CAPACITY_EPS`.

pub mod beta;

use std::collections::HashMap;

use crate::cluster::{Cluster, CAPACITY_EPS};
use crate::config::Policy;
use crate::workload::{AppId, Application, AppState, ComponentId};

/// Per-component demand as computed from the forecast + β buffer, in
/// absolute units (cores / GB).
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    pub cpus: f64,
    pub mem: f64,
}

/// What the shaping pass decided.
#[derive(Debug, Clone, Default)]
pub struct ShapeActions {
    /// Applications to preempt fully (kill + resubmit at original
    /// priority). Controlled preemption — not a failure.
    pub preempt_apps: Vec<AppId>,
    /// Elastic components to preempt individually (partial preemption).
    pub preempt_elastic: Vec<ComponentId>,
    /// New allocations to impose on surviving components.
    pub resizes: Vec<(ComponentId, Demand)>,
}

impl ShapeActions {
    /// Empty the decision lists, keeping their capacity for reuse.
    pub fn clear(&mut self) {
        self.preempt_apps.clear();
        self.preempt_elastic.clear();
        self.resizes.clear();
    }
}

/// Cross-tick scratch for [`plan_into`]: Algorithm 1's per-host free and
/// trial arrays, the per-app core-resize staging list, the elastic sort
/// keys, and the priority order. Holding one of these across ticks makes
/// the planning pass allocation-free in steady state — the seed cloned
/// the full per-host arrays once per running application per tick.
#[derive(Debug, Default)]
pub struct PlanScratch {
    free_cpu: Vec<f64>,
    free_mem: Vec<f64>,
    trial_cpu: Vec<f64>,
    trial_mem: Vec<f64>,
    core_resizes: Vec<(ComponentId, Demand)>,
    /// (placed_at, id) sort keys for one app's elastic components.
    elastic: Vec<(f64, ComponentId)>,
    order: Vec<AppId>,
}

/// Compute shaping actions for the current tick.
///
/// `demands` maps every *placed* component to its desired allocation
/// (forecast peak + β, clamped to the reservation); components absent
/// from the map (e.g. still in grace period) are charged at their current
/// allocation and never preempted partially.
///
/// Allocating convenience wrapper over [`plan_into`] (tests, one-shot
/// callers); the engine holds a [`PlanScratch`] + [`ShapeActions`] pair
/// and calls `plan_into` directly.
pub fn plan(
    policy: Policy,
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
) -> ShapeActions {
    let mut scratch = PlanScratch::default();
    let mut out = ShapeActions::default();
    plan_into(policy, cluster, apps, running, demands, &mut scratch, &mut out);
    out
}

/// [`plan`] writing into caller-owned scratch and output buffers: the
/// allocation-free form for the per-tick hot loop. `out` is cleared
/// first; results are identical to [`plan`] for any scratch history.
pub fn plan_into(
    policy: Policy,
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
    scratch: &mut PlanScratch,
    out: &mut ShapeActions,
) {
    plan_federated(policy, cluster, apps, running, demands, &[], scratch, out);
}

/// [`plan_into`] restricted to one federation shard's control plane:
/// `running` holds only the shard's home applications, and `foreign`
/// lists the *placed* components owned by other shards' applications
/// (overflow placements land them on any host). Foreign components are
/// pre-charged at their **current allocation** into the pessimistic
/// pass's fresh free arrays — they are immovable from this shard's
/// perspective (their own shard's pass resizes them), exactly like the
/// optimistic pass, whose live `free_cpus()`/`free_mem()` arrays already
/// account every current allocation. With `foreign` empty this is
/// [`plan_into`] bit for bit — the monolithic planner is the one-shard
/// special case, not a separate code path.
#[allow(clippy::too_many_arguments)]
pub fn plan_federated(
    policy: Policy,
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
    foreign: &[ComponentId],
    scratch: &mut PlanScratch,
    out: &mut ShapeActions,
) {
    out.clear();
    match policy {
        Policy::Baseline => {}
        Policy::Optimistic => plan_optimistic(cluster, apps, running, demands, scratch, out),
        Policy::Pessimistic => {
            plan_pessimistic(cluster, apps, running, demands, foreign, scratch, out)
        }
    }
}

/// Demand (or current allocation fallback) for a placed component.
fn demand_of(
    cluster: &Cluster,
    demands: &HashMap<ComponentId, Demand>,
    c: ComponentId,
) -> Option<Demand> {
    let p = cluster.placement(c)?;
    Some(demands.get(&c).copied().unwrap_or(Demand {
        cpus: p.alloc_cpus,
        mem: p.alloc_mem,
    }))
}

/// Optimistic: per-host, shrinks are applied unconditionally; growth is
/// granted first-come in app order only up to the host's free room. No
/// preemption — contention surfaces later as OOM kills.
fn plan_optimistic(
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
    scratch: &mut PlanScratch,
    out: &mut ShapeActions,
) {
    let PlanScratch { free_cpu, free_mem, order, .. } = scratch;
    // free room per host after accounting current allocations
    free_cpu.clear();
    free_cpu.extend(cluster.hosts.iter().map(|h| h.free_cpus()));
    free_mem.clear();
    free_mem.extend(cluster.hosts.iter().map(|h| h.free_mem()));
    priority_order_into(apps, running, order);
    for &a in order.iter() {
        for comp in &apps[a].components {
            let Some(p) = cluster.placement(comp.id) else { continue };
            let Some(d) = demand_of(cluster, demands, comp.id) else { continue };
            let grow_cpu = (d.cpus - p.alloc_cpus).max(0.0);
            let grow_mem = (d.mem - p.alloc_mem).max(0.0);
            // grant growth only up to what's free; shrink always granted
            let gc = grow_cpu.min(free_cpu[p.host].max(0.0));
            let gm = grow_mem.min(free_mem[p.host].max(0.0));
            let new = Demand {
                cpus: if d.cpus >= p.alloc_cpus { p.alloc_cpus + gc } else { d.cpus },
                mem: if d.mem >= p.alloc_mem { p.alloc_mem + gm } else { d.mem },
            };
            free_cpu[p.host] -= new.cpus - p.alloc_cpus;
            free_mem[p.host] -= new.mem - p.alloc_mem;
            if (new.cpus - p.alloc_cpus).abs() > CAPACITY_EPS
                || (new.mem - p.alloc_mem).abs() > CAPACITY_EPS
            {
                out.resizes.push((comp.id, new));
            }
        }
    }
}

/// Running apps in scheduler-priority order (FIFO by submit time),
/// written into reused scratch. `total_cmp` keys: a NaN submit time
/// sorts last instead of panicking.
fn priority_order_into(apps: &[Application], running: &[AppId], order: &mut Vec<AppId>) {
    order.clear();
    order.extend_from_slice(running);
    order.sort_by(|&x, &y| {
        apps[x]
            .submit_time
            .total_cmp(&apps[y].submit_time)
            .then(x.cmp(&y))
    });
}

/// Pessimistic: Algorithm 1 of the paper, verbatim structure.
///
/// Walk applications in scheduler order against *fresh* per-host free
/// arrays (lines 1-6). For each app, charge its core components' future
/// demand (lines 11-19): any host overflow ⇒ the whole app goes to K
/// (full preemption, lines 20-21). Otherwise commit and charge its
/// elastic components sorted by time alive — oldest first (line 25) —
/// sending overflowing ones to K_E (partial preemption, lines 26-33).
/// Finally emit preemptions and resizes (lines 34-41).
///
/// The trial arrays live in `scratch` and are refreshed by
/// `copy_from_slice`/`swap` instead of the seed's per-app `clone()`, so
/// the pass never allocates once warm.
///
/// `foreign` components (other shards' placements, see
/// [`plan_federated`]) are pre-charged at current allocation before the
/// walk; the monolithic callers pass `&[]`, leaving the fresh-totals
/// free arrays untouched.
fn plan_pessimistic(
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
    foreign: &[ComponentId],
    scratch: &mut PlanScratch,
    out: &mut ShapeActions,
) {
    let PlanScratch { free_cpu, free_mem, trial_cpu, trial_mem, core_resizes, elastic, order } =
        scratch;
    free_cpu.clear();
    free_cpu.extend(cluster.hosts.iter().map(|h| h.total_cpus));
    free_mem.clear();
    free_mem.extend(cluster.hosts.iter().map(|h| h.total_mem));
    for &c in foreign {
        if let Some(p) = cluster.placement(c) {
            free_cpu[p.host] -= p.alloc_cpus;
            free_mem[p.host] -= p.alloc_mem;
        }
    }
    priority_order_into(apps, running, order);

    for &a in order.iter() {
        let app = &apps[a];
        // --- core components: all-or-nothing ---
        trial_cpu.clear();
        trial_cpu.extend_from_slice(free_cpu);
        trial_mem.clear();
        trial_mem.extend_from_slice(free_mem);
        let mut remove = false;
        core_resizes.clear();
        for comp in app.components.iter().filter(|c| c.is_core) {
            let Some(p) = cluster.placement(comp.id) else {
                // unplaced core: app is restarting; skip
                continue;
            };
            let Some(d) = demand_of(cluster, demands, comp.id) else { continue };
            trial_cpu[p.host] -= d.cpus;
            trial_mem[p.host] -= d.mem;
            if trial_cpu[p.host] < -CAPACITY_EPS || trial_mem[p.host] < -CAPACITY_EPS {
                remove = true;
                break;
            }
            core_resizes.push((comp.id, d));
        }
        if remove {
            out.preempt_apps.push(a);
            continue; // do not commit trial arrays (lines 20-21)
        }
        std::mem::swap(free_cpu, trial_cpu);
        std::mem::swap(free_mem, trial_mem);
        out.resizes.extend_from_slice(core_resizes);

        // --- elastic components: oldest-lived keep resources first ---
        elastic.clear();
        for c in app.components.iter().filter(|c| !c.is_core) {
            if let Some(p) = cluster.placement(c.id) {
                elastic.push((p.placed_at, c.id));
            }
        }
        elastic.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
        for &(_, cid) in elastic.iter() {
            let p = cluster.placement(cid).expect("elastic candidate was placed");
            let Some(d) = demand_of(cluster, demands, cid) else { continue };
            let c_after = free_cpu[p.host] - d.cpus;
            let m_after = free_mem[p.host] - d.mem;
            if c_after < -CAPACITY_EPS || m_after < -CAPACITY_EPS {
                out.preempt_elastic.push(cid);
            } else {
                free_cpu[p.host] = c_after;
                free_mem[p.host] = m_after;
                out.resizes.push((cid, d));
            }
        }
    }
}

/// Sanity check used by tests and debug builds: resizes must never
/// overcommit any host once preemptions are applied.
pub fn validate_actions(
    cluster: &Cluster,
    apps: &[Application],
    actions: &ShapeActions,
) -> Result<(), String> {
    let preempted_apps: std::collections::HashSet<AppId> =
        actions.preempt_apps.iter().copied().collect();
    let preempted_elastic: std::collections::HashSet<ComponentId> =
        actions.preempt_elastic.iter().copied().collect();
    let resized: HashMap<ComponentId, Demand> =
        actions.resizes.iter().copied().collect();
    // component -> owning app, built once (placements carry no app link)
    let owner: HashMap<ComponentId, AppId> = apps
        .iter()
        .flat_map(|a| a.components.iter().map(|c| (c.id, a.id)))
        .collect();
    let mut cpu = vec![0.0; cluster.hosts.len()];
    let mut mem = vec![0.0; cluster.hosts.len()];
    for (&c, p) in cluster.placements() {
        if let Some(a) = owner.get(&c).map(|&a| &apps[a]) {
            if preempted_apps.contains(&a.id) {
                continue;
            }
            if !matches!(a.state, AppState::Running { .. }) {
                continue;
            }
        }
        if preempted_elastic.contains(&c) {
            continue;
        }
        let d = resized
            .get(&c)
            .copied()
            .unwrap_or(Demand { cpus: p.alloc_cpus, mem: p.alloc_mem });
        cpu[p.host] += d.cpus;
        mem[p.host] += d.mem;
    }
    for h in &cluster.hosts {
        if cpu[h.id] > h.total_cpus + CAPACITY_EPS || mem[h.id] > h.total_mem + CAPACITY_EPS {
            return Err(format!(
                "planned allocation overcommits host {}: cpu {:.3}/{:.3} mem {:.3}/{:.3}",
                h.id, cpu[h.id], h.total_cpus, mem[h.id], h.total_mem
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::trace::patterns::{Pattern, PatternKind};
    use crate::workload::Component;

    /// Build a toy world: `napps` single-host apps; each app has one core
    /// plus `nel` elastic components of (1 cpu, 4 GB) on a 1-host cluster.
    fn toy(napps: usize, nel: usize, cpus: f64, mem: f64) -> (Vec<Application>, Cluster) {
        let mut apps = Vec::new();
        let mut cluster = Cluster::new(&ClusterConfig::uniform(1, cpus, mem));
        let mut cid = 0;
        for a in 0..napps {
            let mut components = Vec::new();
            for k in 0..1 + nel {
                components.push(Component {
                    id: cid,
                    app: a,
                    is_core: k == 0,
                    cpu_req: 1.0,
                    mem_req: 4.0,
                    cpu_pattern: Pattern::new(PatternKind::Constant { level: 0.4 }, 1, 0.0),
                    mem_pattern: Pattern::new(PatternKind::Constant { level: 0.4 }, 2, 0.0),
                });
                let ok = cluster.place(cid, 0, 1.0, 4.0, a as f64 * 10.0 + k as f64);
                assert!(ok, "toy cluster too small");
                cid += 1;
            }
            apps.push(Application {
                id: a,
                submit_time: a as f64,
                components,
                total_work: 100.0,
                state: AppState::Running { since: 0.0 },
                remaining_work: 50.0,
                last_progress_at: 0.0,
                failures: 0,
                preemptions: 0,
                shaping_disabled: false,
            });
        }
        (apps, cluster)
    }

    fn uniform_demand(apps: &[Application], cpus: f64, mem: f64) -> HashMap<ComponentId, Demand> {
        apps.iter()
            .flat_map(|a| a.components.iter())
            .map(|c| (c.id, Demand { cpus, mem }))
            .collect()
    }

    #[test]
    fn baseline_never_acts() {
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![0, 1];
        let d = uniform_demand(&apps, 0.1, 0.5);
        let a = plan(Policy::Baseline, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        assert!(a.resizes.is_empty());
    }

    #[test]
    fn pessimistic_shrinks_when_demand_low() {
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![0, 1];
        let d = uniform_demand(&apps, 0.5, 1.0);
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        assert_eq!(a.resizes.len(), 4); // every component resized down
        for (_, dem) in &a.resizes {
            assert_eq!(dem.mem, 1.0);
        }
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn pessimistic_preempts_youngest_elastic_on_pressure() {
        // contend on the CPU axis: capacity 8 cores, memory roomy
        let (apps, cluster) = toy(2, 1, 8.0, 64.0);
        let running = vec![0, 1];
        let d = uniform_demand(&apps, 3.0, 0.5);
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        // cpu capacity 8: core0(3)+elastic0(3)=6, core1(3) -> 9 > 8:
        // app1's core does not fit => app1 fully preempted
        assert_eq!(a.preempt_apps, vec![1]);
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn pessimistic_sheds_elastic_before_core() {
        // one app, lots of elastic: demand grows so only some fit
        let (apps, cluster) = toy(1, 5, 6.0, 64.0);
        let running = vec![0];
        let d = uniform_demand(&apps, 1.5, 1.0);
        // cpu capacity 6: core 1.5 + 3 elastic × 1.5 = 6.0 fits exactly,
        // remaining 2 elastic overflow -> preempted, youngest last placed
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert_eq!(a.preempt_elastic.len(), 2);
        // youngest = highest placed_at = components 4,5 (placed later)
        let mut got = a.preempt_elastic.clone();
        got.sort();
        assert_eq!(got, vec![4, 5]);
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn fifo_priority_protects_older_apps() {
        let (apps, cluster) = toy(3, 0, 4.0, 64.0);
        let running = vec![2, 0, 1]; // shuffled input order
        let d = uniform_demand(&apps, 1.8, 1.0);
        // capacity 4 cpus: apps in FIFO order 0 (1.8), 1 (3.6), 2 -> 5.4
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert_eq!(a.preempt_apps, vec![2]);
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn foreign_precharge_reserves_other_shards_allocations() {
        // two apps share host 0; plan only app 1 as running, with app 0's
        // components foreign (another shard's overflow placements): their
        // live allocation (2 × 1 cpu) must be held back from the walk
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![1];
        let foreign: Vec<ComponentId> = apps[0].components.iter().map(|c| c.id).collect();
        let mut scratch = PlanScratch::default();
        let mut out = ShapeActions::default();
        // effective cpu room 8 − 2 = 6: core 3 + elastic 3 fits exactly
        let d = uniform_demand(&apps, 3.0, 0.5);
        plan_federated(
            Policy::Pessimistic, &cluster, &apps, &running, &d, &foreign, &mut scratch, &mut out,
        );
        assert!(out.preempt_apps.is_empty());
        assert!(out.preempt_elastic.is_empty());
        // core 3.5 + elastic 3.5 = 7 > 6: the elastic overflows
        let d = uniform_demand(&apps, 3.5, 0.5);
        plan_federated(
            Policy::Pessimistic, &cluster, &apps, &running, &d, &foreign, &mut scratch, &mut out,
        );
        assert!(out.preempt_apps.is_empty());
        assert_eq!(out.preempt_elastic, vec![apps[1].components[1].id]);
        // monolithic view of the same demand fits (7 ≤ 8): empty foreign
        // really is the unrestricted planner
        plan_into(Policy::Pessimistic, &cluster, &apps, &running, &d, &mut scratch, &mut out);
        assert!(out.preempt_elastic.is_empty());
    }

    #[test]
    fn optimistic_never_preempts_and_caps_growth() {
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![0, 1];
        // demand above capacity: 4 comps × 4 cpu = 16 > 8 free 4
        let d = uniform_demand(&apps, 4.0, 8.0);
        let a = plan(Policy::Optimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        // growth grants must not exceed free room in aggregate
        let total_cpu: f64 = a
            .resizes
            .iter()
            .map(|(c, dem)| dem.cpus - cluster.placement(*c).unwrap().alloc_cpus)
            .sum();
        assert!(total_cpu <= 8.0 - 4.0 + 1e-9, "granted {total_cpu}");
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn plan_into_with_dirty_scratch_matches_plan() {
        // scratch reuse across ticks (and across policies, and across
        // differently-sized worlds) must never change decisions
        let (apps_a, cluster_a) = toy(2, 3, 8.0, 32.0);
        let (apps_b, cluster_b) = toy(3, 1, 4.0, 24.0);
        let running_a = vec![0, 1];
        let running_b = vec![2, 0, 1];
        let da = uniform_demand(&apps_a, 1.1, 2.0);
        let db = uniform_demand(&apps_b, 1.8, 5.5);
        let mut scratch = PlanScratch::default();
        let mut out = ShapeActions::default();
        for _ in 0..3 {
            for policy in [Policy::Baseline, Policy::Optimistic, Policy::Pessimistic] {
                plan_into(policy, &cluster_a, &apps_a, &running_a, &da, &mut scratch, &mut out);
                let fresh = plan(policy, &cluster_a, &apps_a, &running_a, &da);
                assert_eq!(out.preempt_apps, fresh.preempt_apps, "{policy:?} A");
                assert_eq!(out.preempt_elastic, fresh.preempt_elastic, "{policy:?} A");
                assert_eq!(out.resizes.len(), fresh.resizes.len(), "{policy:?} A");
                for (x, y) in out.resizes.iter().zip(&fresh.resizes) {
                    assert_eq!(x.0, y.0);
                    assert_eq!(x.1.cpus.to_bits(), y.1.cpus.to_bits());
                    assert_eq!(x.1.mem.to_bits(), y.1.mem.to_bits());
                }
                // interleave a differently-shaped world into the same scratch
                plan_into(policy, &cluster_b, &apps_b, &running_b, &db, &mut scratch, &mut out);
                let fresh_b = plan(policy, &cluster_b, &apps_b, &running_b, &db);
                assert_eq!(out.preempt_apps, fresh_b.preempt_apps, "{policy:?} B");
                assert_eq!(out.preempt_elastic, fresh_b.preempt_elastic, "{policy:?} B");
                assert_eq!(out.resizes.len(), fresh_b.resizes.len(), "{policy:?} B");
            }
        }
    }

    #[test]
    fn grace_period_components_keep_allocation() {
        let (apps, cluster) = toy(1, 1, 8.0, 32.0);
        let running = vec![0];
        // empty demand map: everything charged at current allocation
        let d = HashMap::new();
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        // resizes to the same value are emitted; ensure they are no-ops
        for (c, dem) in &a.resizes {
            let p = cluster.placement(*c).unwrap();
            assert_eq!(dem.cpus, p.alloc_cpus);
            assert_eq!(dem.mem, p.alloc_mem);
        }
    }
}
