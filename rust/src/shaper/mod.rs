//! The resource shaper (§3.2): the paper's contribution. Adjusts every
//! running component's allocation toward its forecast utilization plus a
//! safe-guard buffer β (Eq. 9), and decides preemption:
//!
//! * **Baseline** — never shapes; allocation stays at reservation.
//! * **Optimistic** — redeems slack and grows allocations only where room
//!   exists, *without* taking explicit action on contention: when demand
//!   collides, the "OS" OOM-kills at monitor time ([62]-style).
//! * **Pessimistic** — Algorithm 1: recomputes a feasible allocation in
//!   scheduler-priority order, fully preempting applications whose core
//!   components no longer fit and partially preempting elastic components
//!   (youngest first), then resizes the survivors.

pub mod beta;

use std::collections::HashMap;

use crate::cluster::Cluster;
use crate::config::Policy;
use crate::workload::{AppId, Application, AppState, ComponentId};

/// Per-component demand as computed from the forecast + β buffer, in
/// absolute units (cores / GB).
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    pub cpus: f64,
    pub mem: f64,
}

/// What the shaping pass decided.
#[derive(Debug, Clone, Default)]
pub struct ShapeActions {
    /// Applications to preempt fully (kill + resubmit at original
    /// priority). Controlled preemption — not a failure.
    pub preempt_apps: Vec<AppId>,
    /// Elastic components to preempt individually (partial preemption).
    pub preempt_elastic: Vec<ComponentId>,
    /// New allocations to impose on surviving components.
    pub resizes: Vec<(ComponentId, Demand)>,
}

/// Compute shaping actions for the current tick.
///
/// `demands` maps every *placed* component to its desired allocation
/// (forecast peak + β, clamped to the reservation); components absent
/// from the map (e.g. still in grace period) are charged at their current
/// allocation and never preempted partially.
pub fn plan(
    policy: Policy,
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
) -> ShapeActions {
    match policy {
        Policy::Baseline => ShapeActions::default(),
        Policy::Optimistic => plan_optimistic(cluster, apps, running, demands),
        Policy::Pessimistic => plan_pessimistic(cluster, apps, running, demands),
    }
}

/// Demand (or current allocation fallback) for a placed component.
fn demand_of(
    cluster: &Cluster,
    demands: &HashMap<ComponentId, Demand>,
    c: ComponentId,
) -> Option<Demand> {
    let p = cluster.placement(c)?;
    Some(demands.get(&c).copied().unwrap_or(Demand {
        cpus: p.alloc_cpus,
        mem: p.alloc_mem,
    }))
}

/// Optimistic: per-host, shrinks are applied unconditionally; growth is
/// granted first-come in app order only up to the host's free room. No
/// preemption — contention surfaces later as OOM kills.
fn plan_optimistic(
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
) -> ShapeActions {
    let mut actions = ShapeActions::default();
    // free room per host after accounting current allocations
    let mut free_cpu: Vec<f64> = cluster.hosts.iter().map(|h| h.free_cpus()).collect();
    let mut free_mem: Vec<f64> = cluster.hosts.iter().map(|h| h.free_mem()).collect();
    let order = priority_order(apps, running);
    for &a in &order {
        for comp in &apps[a].components {
            let Some(p) = cluster.placement(comp.id) else { continue };
            let Some(d) = demand_of(cluster, demands, comp.id) else { continue };
            let grow_cpu = (d.cpus - p.alloc_cpus).max(0.0);
            let grow_mem = (d.mem - p.alloc_mem).max(0.0);
            // grant growth only up to what's free; shrink always granted
            let gc = grow_cpu.min(free_cpu[p.host].max(0.0));
            let gm = grow_mem.min(free_mem[p.host].max(0.0));
            let new = Demand {
                cpus: if d.cpus >= p.alloc_cpus { p.alloc_cpus + gc } else { d.cpus },
                mem: if d.mem >= p.alloc_mem { p.alloc_mem + gm } else { d.mem },
            };
            free_cpu[p.host] -= new.cpus - p.alloc_cpus;
            free_mem[p.host] -= new.mem - p.alloc_mem;
            if (new.cpus - p.alloc_cpus).abs() > 1e-9 || (new.mem - p.alloc_mem).abs() > 1e-9 {
                actions.resizes.push((comp.id, new));
            }
        }
    }
    actions
}

/// Running apps in scheduler-priority order (FIFO by submit time).
/// `total_cmp` keys: a NaN submit time sorts last instead of panicking.
fn priority_order(apps: &[Application], running: &[AppId]) -> Vec<AppId> {
    let mut order: Vec<AppId> = running.to_vec();
    order.sort_by(|&x, &y| {
        apps[x]
            .submit_time
            .total_cmp(&apps[y].submit_time)
            .then(x.cmp(&y))
    });
    order
}

/// Pessimistic: Algorithm 1 of the paper, verbatim structure.
///
/// Walk applications in scheduler order against *fresh* per-host free
/// arrays (lines 1-6). For each app, charge its core components' future
/// demand (lines 11-19): any host overflow ⇒ the whole app goes to K
/// (full preemption, lines 20-21). Otherwise commit and charge its
/// elastic components sorted by time alive — oldest first (line 25) —
/// sending overflowing ones to K_E (partial preemption, lines 26-33).
/// Finally emit preemptions and resizes (lines 34-41).
fn plan_pessimistic(
    cluster: &Cluster,
    apps: &[Application],
    running: &[AppId],
    demands: &HashMap<ComponentId, Demand>,
) -> ShapeActions {
    let mut actions = ShapeActions::default();
    let mut free_cpu: Vec<f64> = cluster.hosts.iter().map(|h| h.total_cpus).collect();
    let mut free_mem: Vec<f64> = cluster.hosts.iter().map(|h| h.total_mem).collect();

    for &a in &priority_order(apps, running) {
        let app = &apps[a];
        // --- core components: all-or-nothing ---
        let mut trial_cpu = free_cpu.clone();
        let mut trial_mem = free_mem.clone();
        let mut remove = false;
        let mut core_resizes: Vec<(ComponentId, Demand)> = Vec::new();
        for comp in app.components.iter().filter(|c| c.is_core) {
            let Some(p) = cluster.placement(comp.id) else {
                // unplaced core: app is restarting; skip
                continue;
            };
            let Some(d) = demand_of(cluster, demands, comp.id) else { continue };
            trial_cpu[p.host] -= d.cpus;
            trial_mem[p.host] -= d.mem;
            if trial_cpu[p.host] < -1e-9 || trial_mem[p.host] < -1e-9 {
                remove = true;
                break;
            }
            core_resizes.push((comp.id, d));
        }
        if remove {
            actions.preempt_apps.push(a);
            continue; // do not commit trial arrays (lines 20-21)
        }
        free_cpu = trial_cpu;
        free_mem = trial_mem;
        actions.resizes.extend(core_resizes);

        // --- elastic components: oldest-lived keep resources first ---
        let mut elastic: Vec<&crate::workload::Component> = app
            .components
            .iter()
            .filter(|c| !c.is_core && cluster.placement(c.id).is_some())
            .collect();
        elastic.sort_by(|x, y| {
            let px = cluster.placement(x.id).unwrap().placed_at;
            let py = cluster.placement(y.id).unwrap().placed_at;
            px.total_cmp(&py).then(x.id.cmp(&y.id))
        });
        for comp in elastic {
            let p = cluster.placement(comp.id).unwrap();
            let Some(d) = demand_of(cluster, demands, comp.id) else { continue };
            let c_after = free_cpu[p.host] - d.cpus;
            let m_after = free_mem[p.host] - d.mem;
            if c_after < -1e-9 || m_after < -1e-9 {
                actions.preempt_elastic.push(comp.id);
            } else {
                free_cpu[p.host] = c_after;
                free_mem[p.host] = m_after;
                actions.resizes.push((comp.id, d));
            }
        }
    }
    actions
}

/// Sanity check used by tests and debug builds: resizes must never
/// overcommit any host once preemptions are applied.
pub fn validate_actions(
    cluster: &Cluster,
    apps: &[Application],
    actions: &ShapeActions,
) -> Result<(), String> {
    let preempted_apps: std::collections::HashSet<AppId> =
        actions.preempt_apps.iter().copied().collect();
    let preempted_elastic: std::collections::HashSet<ComponentId> =
        actions.preempt_elastic.iter().copied().collect();
    let resized: HashMap<ComponentId, Demand> =
        actions.resizes.iter().copied().collect();
    // component -> owning app, built once (placements carry no app link)
    let owner: HashMap<ComponentId, AppId> = apps
        .iter()
        .flat_map(|a| a.components.iter().map(|c| (c.id, a.id)))
        .collect();
    let mut cpu = vec![0.0; cluster.hosts.len()];
    let mut mem = vec![0.0; cluster.hosts.len()];
    for (&c, p) in cluster.placements() {
        if let Some(a) = owner.get(&c).map(|&a| &apps[a]) {
            if preempted_apps.contains(&a.id) {
                continue;
            }
            if !matches!(a.state, AppState::Running { .. }) {
                continue;
            }
        }
        if preempted_elastic.contains(&c) {
            continue;
        }
        let d = resized
            .get(&c)
            .copied()
            .unwrap_or(Demand { cpus: p.alloc_cpus, mem: p.alloc_mem });
        cpu[p.host] += d.cpus;
        mem[p.host] += d.mem;
    }
    for h in &cluster.hosts {
        if cpu[h.id] > h.total_cpus + 1e-6 || mem[h.id] > h.total_mem + 1e-6 {
            return Err(format!(
                "planned allocation overcommits host {}: cpu {:.3}/{:.3} mem {:.3}/{:.3}",
                h.id, cpu[h.id], h.total_cpus, mem[h.id], h.total_mem
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::trace::patterns::{Pattern, PatternKind};
    use crate::workload::Component;

    /// Build a toy world: `napps` single-host apps; each app has one core
    /// plus `nel` elastic components of (1 cpu, 4 GB) on a 1-host cluster.
    fn toy(napps: usize, nel: usize, cpus: f64, mem: f64) -> (Vec<Application>, Cluster) {
        let mut apps = Vec::new();
        let mut cluster = Cluster::new(&ClusterConfig::uniform(1, cpus, mem));
        let mut cid = 0;
        for a in 0..napps {
            let mut components = Vec::new();
            for k in 0..1 + nel {
                components.push(Component {
                    id: cid,
                    app: a,
                    is_core: k == 0,
                    cpu_req: 1.0,
                    mem_req: 4.0,
                    cpu_pattern: Pattern::new(PatternKind::Constant { level: 0.4 }, 1, 0.0),
                    mem_pattern: Pattern::new(PatternKind::Constant { level: 0.4 }, 2, 0.0),
                });
                let ok = cluster.place(cid, 0, 1.0, 4.0, a as f64 * 10.0 + k as f64);
                assert!(ok, "toy cluster too small");
                cid += 1;
            }
            apps.push(Application {
                id: a,
                submit_time: a as f64,
                components,
                total_work: 100.0,
                state: AppState::Running { since: 0.0 },
                remaining_work: 50.0,
                last_progress_at: 0.0,
                failures: 0,
                preemptions: 0,
                shaping_disabled: false,
            });
        }
        (apps, cluster)
    }

    fn uniform_demand(apps: &[Application], cpus: f64, mem: f64) -> HashMap<ComponentId, Demand> {
        apps.iter()
            .flat_map(|a| a.components.iter())
            .map(|c| (c.id, Demand { cpus, mem }))
            .collect()
    }

    #[test]
    fn baseline_never_acts() {
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![0, 1];
        let d = uniform_demand(&apps, 0.1, 0.5);
        let a = plan(Policy::Baseline, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        assert!(a.resizes.is_empty());
    }

    #[test]
    fn pessimistic_shrinks_when_demand_low() {
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![0, 1];
        let d = uniform_demand(&apps, 0.5, 1.0);
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        assert_eq!(a.resizes.len(), 4); // every component resized down
        for (_, dem) in &a.resizes {
            assert_eq!(dem.mem, 1.0);
        }
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn pessimistic_preempts_youngest_elastic_on_pressure() {
        // contend on the CPU axis: capacity 8 cores, memory roomy
        let (apps, cluster) = toy(2, 1, 8.0, 64.0);
        let running = vec![0, 1];
        let d = uniform_demand(&apps, 3.0, 0.5);
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        // cpu capacity 8: core0(3)+elastic0(3)=6, core1(3) -> 9 > 8:
        // app1's core does not fit => app1 fully preempted
        assert_eq!(a.preempt_apps, vec![1]);
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn pessimistic_sheds_elastic_before_core() {
        // one app, lots of elastic: demand grows so only some fit
        let (apps, cluster) = toy(1, 5, 6.0, 64.0);
        let running = vec![0];
        let d = uniform_demand(&apps, 1.5, 1.0);
        // cpu capacity 6: core 1.5 + 3 elastic × 1.5 = 6.0 fits exactly,
        // remaining 2 elastic overflow -> preempted, youngest last placed
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert_eq!(a.preempt_elastic.len(), 2);
        // youngest = highest placed_at = components 4,5 (placed later)
        let mut got = a.preempt_elastic.clone();
        got.sort();
        assert_eq!(got, vec![4, 5]);
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn fifo_priority_protects_older_apps() {
        let (apps, cluster) = toy(3, 0, 4.0, 64.0);
        let running = vec![2, 0, 1]; // shuffled input order
        let d = uniform_demand(&apps, 1.8, 1.0);
        // capacity 4 cpus: apps in FIFO order 0 (1.8), 1 (3.6), 2 -> 5.4
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert_eq!(a.preempt_apps, vec![2]);
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn optimistic_never_preempts_and_caps_growth() {
        let (apps, cluster) = toy(2, 1, 8.0, 32.0);
        let running = vec![0, 1];
        // demand above capacity: 4 comps × 4 cpu = 16 > 8 free 4
        let d = uniform_demand(&apps, 4.0, 8.0);
        let a = plan(Policy::Optimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        // growth grants must not exceed free room in aggregate
        let total_cpu: f64 = a
            .resizes
            .iter()
            .map(|(c, dem)| dem.cpus - cluster.placement(*c).unwrap().alloc_cpus)
            .sum();
        assert!(total_cpu <= 8.0 - 4.0 + 1e-9, "granted {total_cpu}");
        validate_actions(&cluster, &apps, &a).unwrap();
    }

    #[test]
    fn grace_period_components_keep_allocation() {
        let (apps, cluster) = toy(1, 1, 8.0, 32.0);
        let running = vec![0];
        // empty demand map: everything charged at current allocation
        let d = HashMap::new();
        let a = plan(Policy::Pessimistic, &cluster, &apps, &running, &d);
        assert!(a.preempt_apps.is_empty());
        assert!(a.preempt_elastic.is_empty());
        // resizes to the same value are emitted; ensure they are no-ops
        for (c, dem) in &a.resizes {
            let p = cluster.placement(*c).unwrap();
            assert_eq!(dem.cpus, p.alloc_cpus);
            assert_eq!(dem.mem, p.alloc_mem);
        }
    }
}
