//! # zoe-shaper
//!
//! Production-quality reproduction of **Pace et al. 2018, "A Data-Driven
//! Approach to Dynamically Adjust Resource Allocation for Compute
//! Clusters"** as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the cluster coordinator: discrete-event
//!   simulator, FIFO application scheduler with core/elastic components,
//!   resource monitor, and the paper's contribution, the *resource shaper*
//!   (Algorithm 1 pessimistic preemption + optimistic + baseline).
//! * **L2 (python/compile/model.py)** — GP forecasting posterior in JAX,
//!   AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/gp_kernel.py)** — the Pallas kernel for
//!   the GP's pairwise kernel-matrix hot-spot.
//!
//! Python never runs on the decision path: Rust loads the HLO artifacts
//! via PJRT (`runtime`) and drives all forecasting natively or through the
//! compiled artifacts.
//!
//! See `DESIGN.md` for the module map and the per-figure experiment index,
//! and `EXPERIMENTS.md` for reproduced results.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod federation;
pub mod forecast;
pub mod metrics;
pub mod monitor;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod shaper;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workload;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::config::{ForecasterKind, KernelKind, Policy, SimConfig};
    pub use crate::metrics::RunReport;
    pub use crate::sim::engine::run_simulation;
    pub use crate::util::rng::Pcg;
    pub use crate::util::stats::{boxstats, BoxStats};
}
