"""L2 correctness: GP forecaster vs oracle, batching, and shape checks."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RTOL, ATOL = 2e-3, 2e-3


def _series(rng, t):
    """A plausible standardized utilization series."""
    base = 0.5 * np.sin(np.arange(t) / 5.0) + 0.1 * rng.normal(size=t)
    return base.astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([5, 10, 20]),
    kind=st.sampled_from(["exp", "rbf"]),
    ls=st.floats(0.3, 4.0),
    noise=st.floats(1e-3, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_forecast_matches_ref(h, kind, ls, noise, seed):
    rng = np.random.default_rng(seed)
    x, y, q = ref.make_patterns(_series(rng, 2 * h + 1), h)
    m, v, l = model.gp_forecast(x, y, q, jnp.float32(ls),
                                jnp.float32(noise), kind=kind)
    mr, vr, lr = ref.gp_posterior(x, y, q, ls, noise, kind)
    np.testing.assert_allclose(float(m), float(mr), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(float(v), float(vr), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(float(l), float(lr), rtol=RTOL, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 8),
    h=st.sampled_from([5, 10]),
    kind=st.sampled_from(["exp", "rbf"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_batched_equals_loop(b, h, kind, seed):
    rng = np.random.default_rng(seed)
    xs, ys, qs, lss, nzs = [], [], [], [], []
    for _ in range(b):
        x, y, q = ref.make_patterns(_series(rng, 2 * h + 1), h)
        xs.append(x); ys.append(y); qs.append(q)
        lss.append(rng.uniform(0.5, 2.0)); nzs.append(rng.uniform(0.01, 0.2))
    xb = jnp.stack(xs); yb = jnp.stack(ys); qb = jnp.stack(qs)
    lsb = jnp.array(lss, jnp.float32); nzb = jnp.array(nzs, jnp.float32)
    mb, vb, lb = model.gp_forecast_batched(xb, yb, qb, lsb, nzb, kind=kind)
    for i in range(b):
        m, v, l = model.gp_forecast(xs[i], ys[i], qs[i], lsb[i], nzb[i],
                                    kind=kind)
        np.testing.assert_allclose(float(mb[i]), float(m), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(float(vb[i]), float(v), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(float(lb[i]), float(l), rtol=1e-4,
                                   atol=1e-3)


def test_posterior_variance_shrinks_with_data():
    """More (informative) observations must not increase posterior var."""
    rng = np.random.default_rng(0)
    s = _series(rng, 61)
    h = 10
    x, y, q = ref.make_patterns(s, h)
    v_full = float(model.gp_forecast(x, y, q, jnp.float32(1.0),
                                     jnp.float32(0.05), kind="exp")[1])
    x5, y5 = x[:5], y[:5]
    v_small = float(model.gp_forecast(x5, y5, q, jnp.float32(1.0),
                                      jnp.float32(0.05), kind="exp")[1])
    assert v_full <= v_small + 1e-4


def test_variance_nonnegative_extreme_noise():
    rng = np.random.default_rng(1)
    x, y, q = ref.make_patterns(_series(rng, 21), 10)
    for noise in (1e-6, 1e2):
        v = float(model.gp_forecast(x, y, q, jnp.float32(0.5),
                                    jnp.float32(noise), kind="rbf")[1])
        assert v >= 0.0


def test_interpolation_recovers_training_point():
    """Query equal to a training pattern with tiny noise -> mean ~ target."""
    rng = np.random.default_rng(2)
    x, y, q = ref.make_patterns(_series(rng, 31), 10)
    m = float(model.gp_forecast(x, y, x[7], jnp.float32(1.0),
                                jnp.float32(1e-5), kind="exp")[0])
    assert abs(m - float(y[7])) < 0.05


def test_lml_prefers_true_noise_scale():
    """Evidence maximization signal: lml at a sane noise beats absurd noise."""
    rng = np.random.default_rng(3)
    x, y, q = ref.make_patterns(_series(rng, 41), 10)
    lml_good = float(model.gp_forecast(x, y, q, jnp.float32(1.0),
                                       jnp.float32(0.05), kind="exp")[2])
    lml_bad = float(model.gp_forecast(x, y, q, jnp.float32(1.0),
                                      jnp.float32(50.0), kind="exp")[2])
    assert lml_good > lml_bad


def test_make_patterns_shapes_and_short_series():
    rng = np.random.default_rng(4)
    x, y, q = ref.make_patterns(_series(rng, 25), 10)
    assert x.shape == (15, 11) and y.shape == (15,) and q.shape == (11,)
    with pytest.raises(ValueError):
        ref.make_patterns(_series(rng, 10), 10)
