"""L1 correctness: Pallas kernel vs pure-jnp oracle (the CORE signal).

Hypothesis sweeps shapes, lengthscales and kernel kinds; every case must
match ``ref.kernel_matrix`` to float32 tolerance, and the Gram matrix must
satisfy the structural properties (symmetry, unit-ish diagonal, PSD).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gp_kernel import kernel_matrix_pallas, KERNEL_KINDS

RTOL, ATOL = 1e-4, 1e-5


def _mk(rng, n, p):
    return rng.normal(size=(n, p)).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 48),
    m=st.integers(1, 48),
    p=st.integers(1, 41),
    ls=st.floats(0.1, 8.0),
    var=st.floats(0.1, 4.0),
    kind=st.sampled_from(KERNEL_KINDS),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(n, m, p, ls, var, kind, seed):
    rng = np.random.default_rng(seed)
    x1, x2 = _mk(rng, n, p), _mk(rng, m, p)
    got = np.asarray(kernel_matrix_pallas(x1, x2, ls, var, kind=kind))
    want = np.asarray(ref.kernel_matrix(jnp.array(x1), jnp.array(x2),
                                        ls, var, kind))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 40),
    p=st.integers(1, 41),
    ls=st.floats(0.2, 4.0),
    kind=st.sampled_from(KERNEL_KINDS),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matrix_properties(n, p, ls, kind, seed):
    rng = np.random.default_rng(seed)
    x = _mk(rng, n, p)
    k = np.asarray(kernel_matrix_pallas(x, x, ls, 1.0, kind=kind))
    # symmetry
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-5)
    # diagonal = signal variance (exp kernel has the +1e-12 sqrt guard)
    np.testing.assert_allclose(np.diag(k), np.ones(n), rtol=1e-3, atol=1e-3)
    # PSD up to float32 jitter
    evals = np.linalg.eigvalsh(k.astype(np.float64) + 1e-5 * np.eye(n))
    assert evals.min() > -1e-4


@pytest.mark.parametrize("kind", KERNEL_KINDS)
def test_kernel_value_range(kind):
    rng = np.random.default_rng(7)
    x1, x2 = _mk(rng, 12, 11), _mk(rng, 9, 11)
    k = np.asarray(kernel_matrix_pallas(x1, x2, 1.0, 2.5, kind=kind))
    assert (k > 0).all() and (k <= 2.5 + 1e-5).all()


@pytest.mark.parametrize("kind", KERNEL_KINDS)
def test_identical_points_give_max_kernel(kind):
    x = np.ones((3, 5), np.float32)
    k = np.asarray(kernel_matrix_pallas(x, x, 1.0, 1.0, kind=kind))
    np.testing.assert_allclose(k, np.ones((3, 3)), rtol=1e-3, atol=1e-3)


def test_exp_less_smooth_than_rbf():
    """At moderate distance the exp kernel decays slower than RBF near 0
    but has a kink: check they genuinely differ (guards kind dispatch)."""
    rng = np.random.default_rng(3)
    x1, x2 = _mk(rng, 8, 11), _mk(rng, 8, 11)
    ke = np.asarray(kernel_matrix_pallas(x1, x2, 1.0, 1.0, kind="exp"))
    kr = np.asarray(kernel_matrix_pallas(x1, x2, 1.0, 1.0, kind="rbf"))
    assert np.abs(ke - kr).max() > 1e-3


def test_bad_kind_raises():
    x = np.zeros((2, 3), np.float32)
    with pytest.raises(ValueError):
        kernel_matrix_pallas(x, x, 1.0, 1.0, kind="matern52")


def test_mismatched_pattern_dims_raise():
    with pytest.raises(ValueError):
        kernel_matrix_pallas(np.zeros((2, 3), np.float32),
                             np.zeros((2, 4), np.float32), 1.0, 1.0,
                             kind="exp")


def test_large_tile_path():
    """n > MAX_TILE exercises the multi-step grid."""
    rng = np.random.default_rng(11)
    x1, x2 = _mk(rng, 200, 11), _mk(rng, 16, 11)
    got = np.asarray(kernel_matrix_pallas(x1, x2, 1.0, 1.0, kind="rbf"))
    want = np.asarray(ref.kernel_matrix(jnp.array(x1), jnp.array(x2),
                                        1.0, 1.0, "rbf"))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
