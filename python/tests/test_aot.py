"""AOT pipeline: artifacts lower, parse as HLO text, manifest is coherent.

Executes a freshly lowered module through jax's own CPU client to confirm
the HLO-text round trip preserves numerics (the Rust side repeats this via
the xla crate in rust/tests/runtime_test.rs).
"""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def small_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build_all(str(out), histories=(5,), kinds=("exp",),
                             batch=2)
    return str(out), manifest


def test_manifest_lists_all_files(small_build):
    out, manifest = small_build
    assert len(manifest["artifacts"]) == 2
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["file"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # interchange must be text, never proto bytes
        assert text.isprintable() or "\n" in text


def test_manifest_shapes_consistent(small_build):
    _, manifest = small_build
    for a in manifest["artifacts"]:
        h, b = a["history"], a["batch"]
        p = a["pattern_dim"]
        assert p == h + 1 and a["n_train"] == h
        xt = next(i for i in a["inputs"] if i["name"] == "x_train")
        if b == 1:
            assert xt["shape"] == [h, p]
        else:
            assert xt["shape"] == [b, h, p]


def test_hlo_text_has_no_64bit_id_issue(small_build):
    """Text parse on jax's own client: ids must round-trip."""
    from jax._src.lib import xla_client as xc
    out, manifest = small_build
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        comp = xc.XlaComputation(
            xc._xla.hlo_module_from_text(text).as_serialized_hlo_module_proto()
        )
        assert comp.program_shape() is not None


def test_lowered_module_numerics_match_model():
    """Execute the lowered single-series module via jax and compare."""
    rng = np.random.default_rng(0)
    h = 5
    # artifact expects exactly n = h training patterns -> series length 2h
    series = (0.4 * np.sin(np.arange(2 * h) / 3.0)
              + 0.05 * rng.normal(size=2 * h)).astype(np.float32)
    x, y, q = ref.make_patterns(series, h)
    lowered, _ = aot.lower_single("exp", h)
    compiled = lowered.compile()
    ls = jnp.float32(1.0)
    nz = jnp.float32(0.05)
    got = compiled(x, y, q, ls, nz)
    want = model.gp_forecast(x, y, q, ls, nz, kind="exp")
    for g, w in zip(got, want):
        np.testing.assert_allclose(float(g), float(w), rtol=1e-5, atol=1e-5)


def test_default_artifacts_if_present():
    """When `make artifacts` has run, the shipped manifest must be sane."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts/ not built yet")
    manifest = json.load(open(mpath))
    names = {a["name"] for a in manifest["artifacts"]}
    for kind in ("exp", "rbf"):
        for h in (10, 20, 40):
            assert f"gp_{kind}_h{h}" in names
            assert f"gp_{kind}_h{h}_b32" in names
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(root, a["file"]))
