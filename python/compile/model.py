"""L2 — the GP forecasting model (jax), calling the L1 Pallas kernel.

This is the compute graph the Rust coordinator executes on its hot path
(via the AOT HLO artifacts emitted by ``aot.py``). It implements the
paper's §3.1.2 GP regression over history patterns:

  * ``gp_forecast``         — one series: posterior (mean, var, lml)
  * ``gp_forecast_batched`` — B series at once (the realistic hot-path
    shape: the resource shaper forecasts every running component each
    tick, so Rust batches components into fixed-size B slabs)

Hyper-parameters (lengthscale, observation-noise variance) are *runtime
inputs*, not baked constants: the Rust side performs the paper's evidence
maximization (§3.1) by re-invoking the same artifact over a small grid and
picking the lengthscale with the highest returned ``lml``.

Shapes are static per artifact: history window ``h`` (pattern dim
``p = h+1``), training-set size ``n`` (the paper uses N = h), batch ``b``.
``aot.py`` emits one artifact per (kernel kind, h, batch) combination.
"""

import jax
import jax.numpy as jnp

from .kernels.gp_kernel import kernel_matrix_pallas

__all__ = ["gp_forecast", "gp_forecast_batched", "JITTER",
           "cholesky_unrolled", "solve_lower_unrolled",
           "solve_upper_unrolled"]

# Numerical jitter added on top of the runtime noise input; keeps the
# Cholesky factorization stable for near-duplicate history patterns.
JITTER = 1e-6


# --- pure-jnp linear algebra -------------------------------------------
#
# jax.lax.linalg.{cholesky,triangular_solve} lower to LAPACK custom-calls
# on CPU (API_VERSION_TYPED_FFI), which the xla crate's xla_extension
# 0.5.1 PJRT client rejects at compile time. The GP shapes are tiny and
# *static* (n = h <= 40), so we unroll textbook column-Cholesky and
# substitution into plain HLO ops instead — fully portable, and XLA still
# fuses the column updates. aot.py asserts no custom-call survives.

def cholesky_unrolled(a):
    """Lower-Cholesky of a static-shape SPD matrix, plain jnp ops only."""
    n = a.shape[0]
    l = jnp.zeros_like(a)
    for j in range(n):
        if j == 0:
            d = jnp.sqrt(a[0, 0])
            l = l.at[0, 0].set(d)
            if n > 1:
                l = l.at[1:, 0].set(a[1:, 0] / d)
        else:
            d = jnp.sqrt(a[j, j] - jnp.sum(l[j, :j] * l[j, :j]))
            l = l.at[j, j].set(d)
            if j + 1 < n:
                col = (a[j + 1:, j] - l[j + 1:, :j] @ l[j, :j]) / d
                l = l.at[j + 1:, j].set(col)
    return l


def solve_lower_unrolled(l, b):
    """Solve L x = b (L lower-triangular, static shape)."""
    n = l.shape[0]
    x = jnp.zeros_like(b)
    for i in range(n):
        s = b[i] if i == 0 else b[i] - l[i, :i] @ x[:i]
        x = x.at[i].set(s / l[i, i])
    return x


def solve_upper_unrolled(l, b):
    """Solve Lᵀ x = b (L lower-triangular, static shape)."""
    n = l.shape[0]
    x = jnp.zeros_like(b)
    for i in reversed(range(n)):
        s = b[i] if i == n - 1 else b[i] - l[i + 1:, i] @ x[i + 1:]
        x = x.at[i].set(s / l[i, i])
    return x


def gp_forecast(x_train, y_train, x_query, lengthscale, noise, *, kind):
    """Posterior (mean, var, lml) for one series. See ref.gp_posterior.

    Args:
      x_train: ``(n, p)`` history patterns (Eq. 5 rows).
      y_train: ``(n,)`` targets (values following each pattern).
      x_query: ``(p,)`` query pattern (most recent history).
      lengthscale: scalar f32, runtime input.
      noise: scalar f32, observation-noise variance, runtime input.
      kind: "exp" | "rbf" — static; selects the Pallas kernel variant.

    Returns:
      Tuple of f32 scalars ``(mean, var, lml)``.
    """
    n = x_train.shape[0]
    x_train = x_train.astype(jnp.float32)
    y_train = y_train.astype(jnp.float32)
    x_query = x_query.astype(jnp.float32)

    # Signal variance fixed to 1: Rust standardizes y before the call, so
    # unit signal variance is the correct prior scale (DESIGN.md §2).
    variance = jnp.float32(1.0)

    kxx = kernel_matrix_pallas(x_train, x_train, lengthscale, variance,
                               kind=kind)
    kxx = kxx + (noise + JITTER) * jnp.eye(n, dtype=jnp.float32)
    kxq = kernel_matrix_pallas(x_query[None, :], x_train, lengthscale,
                               variance, kind=kind)[0]          # (n,)

    chol = cholesky_unrolled(kxx)
    # alpha = K^{-1} y via two triangular solves.
    z = solve_lower_unrolled(chol, y_train)
    alpha = solve_upper_unrolled(chol, z)

    mean = kxq @ alpha
    v = solve_lower_unrolled(chol, kxq)
    var = jnp.maximum(variance - v @ v, 0.0)

    lml = (-0.5 * (y_train @ alpha)
           - jnp.sum(jnp.log(jnp.diagonal(chol)))
           - 0.5 * n * jnp.log(2.0 * jnp.pi).astype(jnp.float32))
    return mean, var, lml


def gp_forecast_batched(x_train, y_train, x_query, lengthscale, noise, *,
                        kind):
    """Vectorized ``gp_forecast`` over a leading batch dimension.

    Args:
      x_train: ``(b, n, p)``; y_train: ``(b, n)``; x_query: ``(b, p)``;
      lengthscale, noise: ``(b,)`` per-series hyper-parameters.

    Returns:
      ``(means, vars, lmls)``, each ``(b,)`` f32.
    """
    fn = lambda xt, yt, xq, ls, nz: gp_forecast(xt, yt, xq, ls, nz,
                                                kind=kind)
    return jax.vmap(fn)(x_train, y_train, x_query, lengthscale, noise)
