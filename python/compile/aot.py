"""AOT lowering: jax/Pallas GP forecaster -> HLO text artifacts for Rust.

Emits one HLO module per (kernel kind, history window h, batch size)
combination, plus ``manifest.json`` describing shapes so the Rust runtime
(``rust/src/runtime``) can validate its inputs before execution.

Interchange format is HLO **text**, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage (from ``make artifacts``):
    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The paper (Fig. 2) evaluates history windows h in {10, 20, 40} with
# N = h stored patterns; pattern dim p = h + 1 (Eq. 5: time + h values).
HISTORIES = (10, 20, 40)
KINDS = ("exp", "rbf")
# Hot-path batch: the Rust shaper slabs per-component forecasts into
# fixed-size batches and pads the tail slab.
BATCH = 32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    # The Rust-side xla_extension 0.5.1 cannot execute typed-FFI
    # custom-calls (e.g. LAPACK lowerings); model.py uses unrolled pure-jnp
    # linear algebra precisely to avoid them. Fail the build if one leaks.
    assert "custom-call" not in text, (
        "lowered HLO contains a custom-call; the Rust PJRT client cannot "
        "run it — replace the offending op with pure-jnp code in model.py"
    )
    return text


def lower_single(kind: str, h: int):
    """Lower the single-series forecaster for history window ``h``."""
    n, p = h, h + 1
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((n, p), f32),   # x_train
        jax.ShapeDtypeStruct((n,), f32),     # y_train
        jax.ShapeDtypeStruct((p,), f32),     # x_query
        jax.ShapeDtypeStruct((), f32),       # lengthscale
        jax.ShapeDtypeStruct((), f32),       # noise
    )
    fn = lambda xt, yt, xq, ls, nz: model.gp_forecast(xt, yt, xq, ls, nz,
                                                      kind=kind)
    return jax.jit(fn).lower(*specs), {
        "inputs": [
            {"name": "x_train", "shape": [n, p]},
            {"name": "y_train", "shape": [n]},
            {"name": "x_query", "shape": [p]},
            {"name": "lengthscale", "shape": []},
            {"name": "noise", "shape": []},
        ],
        "outputs": [
            {"name": "mean", "shape": []},
            {"name": "var", "shape": []},
            {"name": "lml", "shape": []},
        ],
    }


def lower_batched(kind: str, h: int, b: int):
    """Lower the batched forecaster: the Rust hot-path artifact."""
    n, p = h, h + 1
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((b, n, p), f32),
        jax.ShapeDtypeStruct((b, n), f32),
        jax.ShapeDtypeStruct((b, p), f32),
        jax.ShapeDtypeStruct((b,), f32),
        jax.ShapeDtypeStruct((b,), f32),
    )
    fn = lambda xt, yt, xq, ls, nz: model.gp_forecast_batched(
        xt, yt, xq, ls, nz, kind=kind)
    return jax.jit(fn).lower(*specs), {
        "inputs": [
            {"name": "x_train", "shape": [b, n, p]},
            {"name": "y_train", "shape": [b, n]},
            {"name": "x_query", "shape": [b, p]},
            {"name": "lengthscale", "shape": [b]},
            {"name": "noise", "shape": [b]},
        ],
        "outputs": [
            {"name": "means", "shape": [b]},
            {"name": "vars", "shape": [b]},
            {"name": "lmls", "shape": [b]},
        ],
    }


def build_all(out_dir: str, histories=HISTORIES, kinds=KINDS, batch=BATCH):
    """Lower every artifact variant into ``out_dir``; return the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for kind in kinds:
        for h in histories:
            for tag, (lowered, sig) in (
                (f"gp_{kind}_h{h}", lower_single(kind, h)),
                (f"gp_{kind}_h{h}_b{batch}", lower_batched(kind, h, batch)),
            ):
                path = os.path.join(out_dir, f"{tag}.hlo.txt")
                text = to_hlo_text(lowered)
                with open(path, "w") as f:
                    f.write(text)
                entry = {
                    "name": tag,
                    "file": f"{tag}.hlo.txt",
                    "kind": kind,
                    "history": h,
                    "n_train": h,
                    "pattern_dim": h + 1,
                    "batch": batch if "_b" in tag else 1,
                    **sig,
                }
                manifest["artifacts"].append(entry)
                print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for *.hlo.txt + manifest.json")
    ap.add_argument("--histories", default=",".join(map(str, HISTORIES)),
                    help="comma-separated history windows")
    ap.add_argument("--kinds", default=",".join(KINDS))
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()
    build_all(
        args.out,
        histories=tuple(int(x) for x in args.histories.split(",")),
        kinds=tuple(args.kinds.split(",")),
        batch=args.batch,
    )


if __name__ == "__main__":
    main()
