"""L1 — Pallas kernels for the GP hot-spot: pairwise kernel matrices.

The compute hot-spot of the paper's GP forecaster (§3.1.2) is building the
history-pattern kernel matrix ``k_h(X, X')`` (Eq. 6) every shaping tick,
for every running application component. We lower it as a Pallas kernel so
the whole posterior computation (model.py) fuses into one HLO module that
the Rust coordinator executes via PJRT.

TPU mapping (see DESIGN.md §Hardware-Adaptation): squared distances are
computed with the ``‖a‖² + ‖b‖² − 2·a·bᵀ`` decomposition so the dominant
term is a matmul that maps onto the MXU; row blocks of X1/X2 are staged
into VMEM by BlockSpec. For the paper's shapes (N = h ≤ 40, P = h+1 ≤ 41)
a single grid step holds everything in VMEM; the batched variant in
model.py vmaps this kernel over B series, which is the realistic
TPU-efficiency shape analyzed in EXPERIMENTS.md §Perf.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both jax-CPU and the
Rust xla-crate client run bit-identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kernel_matrix_pallas", "KERNEL_KINDS"]

KERNEL_KINDS = ("exp", "rbf")

# Row-tile size. Shapes in this system are small (N <= 64); keep one tile
# unless the first dimension grows beyond MAX_TILE rows.
MAX_TILE = 128


def _kernel_body(x1_ref, x2_ref, ls_ref, var_ref, o_ref, *, kind):
    """Pallas body: one (tile_n, m) block of the kernel matrix.

    x1_ref: (tile_n, p) block of left patterns   (VMEM)
    x2_ref: (m, p)      all right patterns        (VMEM)
    ls_ref, var_ref: (1, 1) scalar params in SMEM-like blocks
    o_ref:  (tile_n, m) output block              (VMEM)
    """
    x1 = x1_ref[...]
    x2 = x2_ref[...]
    ls = ls_ref[0, 0]
    var = var_ref[0, 0]

    # ||a||^2 + ||b||^2 - 2 a.b^T : the 2ab^T term is the MXU matmul.
    n1 = jnp.sum(x1 * x1, axis=-1, keepdims=True)          # (tile_n, 1)
    n2 = jnp.sum(x2 * x2, axis=-1, keepdims=True).T        # (1, m)
    cross = jax.lax.dot_general(
        x1, x2,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (tile_n, m)
    d2 = jnp.maximum(n1 + n2 - 2.0 * cross, 0.0)

    if kind == "exp":
        d = jnp.sqrt(d2 + 1e-12)
        o_ref[...] = var * jnp.exp(-d / ls)
    else:  # rbf
        o_ref[...] = var * jnp.exp(-0.5 * d2 / (ls * ls))


@functools.partial(jax.jit, static_argnames=("kind",))
def kernel_matrix_pallas(x1, x2, lengthscale, variance, kind="exp"):
    """Pairwise kernel matrix via Pallas. Matches ``ref.kernel_matrix``.

    Args:
      x1: ``(n, p)`` float32 patterns.
      x2: ``(m, p)`` float32 patterns.
      lengthscale: scalar float32.
      variance: scalar float32 signal variance.
      kind: "exp" | "rbf" (static).

    Returns:
      ``(n, m)`` float32 kernel matrix.
    """
    if kind not in KERNEL_KINDS:
        raise ValueError(f"unknown kernel kind: {kind!r}")
    n, p = x1.shape
    m, p2 = x2.shape
    if p != p2:
        raise ValueError(f"pattern dims differ: {p} vs {p2}")

    x1 = x1.astype(jnp.float32)
    x2 = x2.astype(jnp.float32)
    ls = jnp.reshape(jnp.asarray(lengthscale, jnp.float32), (1, 1))
    var = jnp.reshape(jnp.asarray(variance, jnp.float32), (1, 1))

    tile_n = min(n, MAX_TILE)
    # Grid over row tiles of x1; x2 is broadcast to every step. With the
    # paper's shapes the grid is a single step and the whole working set
    # sits in VMEM (see EXPERIMENTS.md §Perf for the footprint estimate).
    grid = (pl.cdiv(n, tile_n),)

    return pl.pallas_call(
        functools.partial(_kernel_body, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, p), lambda i: (i, 0)),
            pl.BlockSpec((m, p), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(x1, x2, ls, var)
