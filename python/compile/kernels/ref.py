"""Pure-jnp reference oracle for the GP forecasting math.

This module is the single source of truth for correctness: the Pallas
kernels in ``gp_kernel.py`` and the lowered L2 model in ``model.py`` are
checked against these functions by pytest (``python/tests``) and, across
the language boundary, by ``rust/tests/gp_cross_validation.rs`` (the
native-Rust GP mirrors the same equations).

The paper (§3.1.2) models a utilization time series with a GP over
*history patterns*: each input is ``x̃_t = [t, y_{t-h}, ..., y_{t-1}]``
(Eq. 5) and the kernel is a standard exponential / squared-exponential
kernel applied to the transformed inputs (Eq. 6). The posterior mean and
variance are the textbook GP regression equations (Eq. 7-8).
"""

import jax.numpy as jnp

__all__ = [
    "sqdist",
    "kernel_exp",
    "kernel_rbf",
    "kernel_matrix",
    "gp_posterior",
    "solve_chol",
    "make_patterns",
]


def sqdist(x1, x2):
    """Pairwise squared Euclidean distances.

    Args:
      x1: ``(n, p)`` array.
      x2: ``(m, p)`` array.
    Returns:
      ``(n, m)`` array of squared distances, clamped to ``>= 0`` so that
      downstream ``sqrt`` never sees a tiny negative from cancellation.
    """
    n1 = jnp.sum(x1 * x1, axis=-1, keepdims=True)  # (n, 1)
    n2 = jnp.sum(x2 * x2, axis=-1, keepdims=True).T  # (1, m)
    d2 = n1 + n2 - 2.0 * (x1 @ x2.T)
    return jnp.maximum(d2, 0.0)


def kernel_exp(x1, x2, lengthscale, variance):
    """Exponential (Matern-1/2) kernel on history patterns.

    ``k(a, b) = variance * exp(-|a - b| / lengthscale)``.
    The paper's preferred kernel (GP-Exp in Fig. 2): utilization series are
    not smooth, so the non-differentiable exponential kernel wins.
    """
    d = jnp.sqrt(sqdist(x1, x2) + 1e-12)
    return variance * jnp.exp(-d / lengthscale)


def kernel_rbf(x1, x2, lengthscale, variance):
    """Squared-exponential (RBF) kernel: the GP-RBF comparator in Fig. 2."""
    d2 = sqdist(x1, x2)
    return variance * jnp.exp(-0.5 * d2 / (lengthscale * lengthscale))


def kernel_matrix(x1, x2, lengthscale, variance, kind):
    """Dispatch on kernel ``kind`` in {"exp", "rbf"}."""
    if kind == "exp":
        return kernel_exp(x1, x2, lengthscale, variance)
    if kind == "rbf":
        return kernel_rbf(x1, x2, lengthscale, variance)
    raise ValueError(f"unknown kernel kind: {kind!r}")


def gp_posterior(x_train, y_train, x_query, lengthscale, noise, kind,
                 variance=1.0):
    """GP regression posterior at a single query pattern (Eq. 7-8).

    Args:
      x_train: ``(n, p)`` history patterns (Eq. 5 rows).
      y_train: ``(n,)`` observed next values.
      x_query: ``(p,)`` query pattern.
      lengthscale: kernel lengthscale (scalar).
      noise: observation-noise *variance* sigma^2 (scalar).
      kind: "exp" | "rbf".
      variance: kernel signal variance.

    Returns:
      ``(mean, var, lml)`` scalars: posterior mean, posterior variance
      (clamped >= 0) and the log marginal likelihood of the training set —
      the evidence used for hyper-parameter selection (§3.1).
    """
    n = x_train.shape[0]
    kxx = kernel_matrix(x_train, x_train, lengthscale, variance, kind)
    kxx = kxx + (noise + 1e-6) * jnp.eye(n, dtype=x_train.dtype)
    kxq = kernel_matrix(x_query[None, :], x_train, lengthscale, variance,
                        kind)[0]  # (n,)
    kqq = variance

    chol = jnp.linalg.cholesky(kxx)
    alpha = solve_chol(chol, y_train)
    mean = kxq @ alpha
    v = jnp.linalg.solve(chol, kxq)  # lower-triangular solve
    var = jnp.maximum(kqq - v @ v, 0.0)

    # log marginal likelihood: -1/2 yᵀ α - Σ log L_ii - n/2 log 2π
    lml = (-0.5 * (y_train @ alpha)
           - jnp.sum(jnp.log(jnp.diagonal(chol)))
           - 0.5 * n * jnp.log(2.0 * jnp.pi))
    return mean, var, lml


def solve_chol(chol, b):
    """Solve ``K x = b`` given the lower Cholesky factor of ``K``."""
    z = jnp.linalg.solve(chol, b)
    return jnp.linalg.solve(chol.T, z)


def make_patterns(series, h):
    """Build the (Eq. 5) training set from a raw utilization series.

    Row ``i`` is ``[t_i, y_{i}, ..., y_{i+h-1}]`` with target
    ``y_{i+h}``; times are scaled to [0, 1] so one lengthscale governs
    both the time coordinate and the (standardized) history values.

    Returns ``(X, y, q)`` where ``q`` is the query pattern predicting the
    value after the final observation.
    """
    series = jnp.asarray(series)
    t = series.shape[0]
    if t <= h:
        raise ValueError(f"series of length {t} too short for history {h}")
    rows = []
    targets = []
    for i in range(t - h):
        rows.append(jnp.concatenate(
            [jnp.array([i / t], dtype=series.dtype), series[i:i + h]]))
        targets.append(series[i + h])
    x = jnp.stack(rows)
    y = jnp.stack(targets)
    q = jnp.concatenate(
        [jnp.array([(t - h) / t], dtype=series.dtype), series[t - h:]])
    return x, y, q
