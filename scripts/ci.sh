#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite (ROADMAP.md).
#
# PJRT-dependent tests self-skip when no AOT artifact dir / `pjrt`
# feature is present, so this runs green on a bare Rust toolchain.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
# compile coverage for harness=false benches and the examples, which
# `build`/`test` alone never touch
cargo build --release --benches --examples
# and under the bench profile specifically, so bench-only code can't rot
cargo bench --no-run
cargo test -q
# scalar-fallback gate: the whole suite must also pass with the SIMD
# dispatcher forced off (ZOE_SIMD=off), pinning the portable code path
# on machines where the vector path is what usually runs
ZOE_SIMD=off cargo test -q
# engine-mode gate: the whole suite must also pass with the
# event-driven core (quiet-tick elision) as the default engine —
# every run_simulation* call that doesn't pin a mode then exercises
# the elided path, and the golden suites keep pinning both modes
# explicitly regardless of this override
ZOE_ENGINE_MODE=event-driven cargo test -q

# docs gate: rustdoc must build warning-free (broken intra-doc links,
# bad code fences, missing docs on public items referenced from docs/)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# lint gate: clippy across every target (skipped gracefully on
# toolchains without the clippy component)
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "cargo clippy unavailable; skipping lint gate"
fi
