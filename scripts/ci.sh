#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite (ROADMAP.md).
#
# PJRT-dependent tests self-skip when no AOT artifact dir / `pjrt`
# feature is present, so this runs green on a bare Rust toolchain.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
# compile coverage for harness=false benches and the examples, which
# `build`/`test` alone never touch
cargo build --release --benches --examples
# and under the bench profile specifically, so bench-only code can't rot
cargo bench --no-run
cargo test -q
# scalar-fallback gate: the whole suite must also pass with the SIMD
# dispatcher forced off (ZOE_SIMD=off), pinning the portable code path
# on machines where the vector path is what usually runs
ZOE_SIMD=off cargo test -q
# engine-mode gate: the whole suite must also pass with the
# event-driven core (quiet-tick elision) as the default engine —
# every run_simulation* call that doesn't pin a mode then exercises
# the elided path, and the golden suites keep pinning both modes
# explicitly regardless of this override
ZOE_ENGINE_MODE=event-driven cargo test -q
# federation gate: the whole suite must also pass with 4 coordinator
# shards as the default control plane — every run_simulation* call that
# doesn't pin a shard count then exercises the federated admission /
# overflow path, while the golden and property suites pin their shard
# counts via Engine::set_shards and so keep asserting the monolithic
# and N-shard contracts explicitly regardless of this override
ZOE_SHARDS=4 cargo test -q

# chaos smoke: a seeded fault-injection run (crashes + telemetry
# dropouts/corruption + forecaster faults) must complete and report
# non-zero fault accounting in the JSON — the graceful-degradation path
# stays alive end-to-end, not just under the unit/property suites.
# Long jobs pin the cluster busy across the whole 3-day horizon so the
# seeded fault windows always land on live components; the corruption
# rate rides in via CLI flag to smoke that plumbing too.
CHAOS_CFG="$(mktemp)" CHAOS_JSON="$(mktemp)"
cat > "$CHAOS_CFG" <<'EOF'
{
  "cluster": { "hosts": 6 },
  "workload": { "num_apps": 80, "runtime_scale": 20.0 },
  "max_sim_time_s": 259200,
  "faults": {
    "crash_rate_per_host_day": 1.0, "crash_downtime_mean_s": 3600.0,
    "dropout_rate_per_day": 4.0, "forecast_fault_rate_per_day": 2.0
  }
}
EOF
./target/release/zoe-shaper simulate --preset small --config "$CHAOS_CFG" \
    --corruption-rate 2 --json-out "$CHAOS_JSON" >/dev/null
grep -q '"crashes_injected":' "$CHAOS_JSON"
if grep -q '"crashes_injected": *0[,}]' "$CHAOS_JSON"; then
    echo "chaos smoke: no crashes injected" >&2
    exit 1
fi
if grep -q '"samples_dropped": *0[,}]' "$CHAOS_JSON"; then
    echo "chaos smoke: no telemetry samples dropped" >&2
    exit 1
fi
rm -f "$CHAOS_CFG" "$CHAOS_JSON"

# scenario gate: every bundled scenario file must parse + validate
# through the real loader (no simulation), so a broken scenarios/*.json
# can never ship — loader errors name the offending step.
for f in ../scenarios/*.json; do
    ./target/release/zoe-shaper scenarios --validate "$f" >/dev/null
done

# scenario smoke: one fast library scenario end-to-end — the replayed
# step counter in the report JSON must be non-zero, proving the timed
# steps actually fired rather than the scenario silently compiling away.
SCEN_JSON="$(mktemp)"
./target/release/zoe-shaper simulate --preset small --apps 40 \
    --scenario-file ../scenarios/diurnal.json --json-out "$SCEN_JSON" >/dev/null
grep -q '"scenario_steps":' "$SCEN_JSON"
if grep -q '"scenario_steps": *0[,}]' "$SCEN_JSON"; then
    echo "scenario smoke: no scenario steps replayed" >&2
    exit 1
fi
rm -f "$SCEN_JSON"

# docs gate: rustdoc must build warning-free (broken intra-doc links,
# bad code fences, missing docs on public items referenced from docs/)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

# lint gate: clippy across every target (skipped gracefully on
# toolchains without the clippy component)
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "cargo clippy unavailable; skipping lint gate"
fi
